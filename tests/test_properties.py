"""E3 (property tier): hypothesis tests for the system's invariants —
quantization math, §3.1 decomposition, artifact conformance (runtime ≡
compiled, bit-exact), serialization, kernel wrapper vs oracle."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import patterns, pqir, quant
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime

SETTINGS = dict(deadline=None, max_examples=30)


class TestQuantInvariants:
    @settings(**SETTINGS)
    @given(
        st.floats(min_value=1e-6, max_value=1e6, allow_nan=False, allow_infinity=False)
    )
    def test_decompose_precision_and_exactness(self, m):
        r = quant.decompose_multiplier(m)
        assert 1 <= r.quant_scale < 2**24
        assert np.float32(r.quant_scale) == r.quant_scale  # exact as FLOAT (goal 4)
        assert abs(r.realized - m) / m < 2.0**-23

    @settings(**SETTINGS)
    @given(
        st.lists(st.floats(min_value=-1e4, max_value=1e4, width=32), min_size=1, max_size=256),
        st.sampled_from(["int8", "uint8"]),
    )
    def test_roundtrip_error_bound(self, xs, dtype):
        x = np.asarray(xs, np.float32)
        if dtype == "uint8":
            x = np.abs(x)
        absmax = float(np.abs(x).max())
        if absmax == 0.0:
            return
        s = quant.choose_scale(absmax, dtype)
        err = np.abs(quant.dequantize(quant.quantize(x, s, dtype), s) - x)
        assert float(err.max()) <= s / 2 + 1e-6 * absmax

    @settings(**SETTINGS)
    @given(st.lists(st.floats(min_value=-100, max_value=100, width=32), min_size=2, max_size=64))
    def test_quantize_monotone(self, xs):
        x = np.sort(np.asarray(xs, np.float32))
        q = quant.quantize(x, 0.5, "int8").astype(np.int32)
        assert (np.diff(q) >= 0).all()

    @settings(**SETTINGS)
    @given(st.integers(min_value=-(2**20), max_value=2**20))
    def test_rescale_reference_matches_float64(self, acc):
        r = quant.decompose_multiplier(1 / 7)
        got = quant.apply_rescale_reference(np.asarray([acc], np.int32), r, "int8")[0]
        expect = np.clip(np.rint(acc * r.quant_scale * 2.0**-r.shift), -128, 127)
        assert int(got) == int(expect)


class TestArtifactConformance:
    @settings(deadline=None, max_examples=15)
    @given(
        n_in=st.integers(min_value=1, max_value=96),
        n_out=st.integers(min_value=1, max_value=96),
        batch=st.integers(min_value=1, max_value=8),
        two_mul=st.booleans(),
        activation=st.sampled_from([None, "Relu"]),
        with_bias=st.booleans(),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_fc_compiled_bitexact_vs_runtime(self, n_in, n_out, batch, two_mul, activation, with_bias, seed):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(n_in, n_out)).astype(np.float32) * 0.2
        b = rng.normal(size=(n_out,)).astype(np.float32) * 0.1 if with_bias else None
        p = quant.quantize_linear_layer(w, b, 0.05, 0.1)
        xq = rng.integers(-128, 128, (batch, n_in)).astype(np.int8)
        gb = pqir.GraphBuilder("prop")
        xi = gb.add_input("x", "int8", (None, n_in))
        y = patterns.fc_layer(gb, xi, p, "fc0", two_mul=two_mul, activation=activation)
        gb.add_output(y, "int8", (None, n_out))
        model = gb.build()
        ref = ReferenceRuntime(model).run({"x": xq})[y]
        got = compile_model(model).run({"x": xq})[y]
        np.testing.assert_array_equal(got, ref)

    @settings(deadline=None, max_examples=10)
    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        act=st.sampled_from(["int8_tanh", "fp16_tanh", "fp16_sigmoid"]),
    )
    def test_activation_lut_bitexact(self, seed, act):
        rng = np.random.default_rng(seed)
        w = rng.normal(size=(24, 16)).astype(np.float32) * 0.3
        p = quant.quantize_linear_layer(w, None, 0.05, patterns.TANH_INPUT_ABSMAX / 127.0)
        xq = rng.integers(-128, 128, (4, 24)).astype(np.int8)
        gb = pqir.GraphBuilder("prop")
        xi = gb.add_input("x", "int8", (None, 24))
        fn = {"int8_tanh": patterns.fc_int8_tanh, "fp16_tanh": patterns.fc_fp16_tanh, "fp16_sigmoid": patterns.fc_fp16_sigmoid}[act]
        y = fn(gb, xi, p, "fc0")
        gb.add_output(y, "uint8" if act == "fp16_sigmoid" else "int8", (None, 16))
        model = gb.build()
        ref = ReferenceRuntime(model).run({"x": xq})[y]
        got = compile_model(model).run({"x": xq})[y]
        np.testing.assert_array_equal(got, ref)

    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_serialization_roundtrip_preserves_semantics(self, seed):
        import json

        rng = np.random.default_rng(seed)
        w = rng.normal(size=(16, 8)).astype(np.float32) * 0.2
        p = quant.quantize_linear_layer(w, None, 0.05, 0.1)
        gb = pqir.GraphBuilder("ser")
        xi = gb.add_input("x", "int8", (None, 16))
        y = patterns.fc_layer(gb, xi, p, "fc0")
        gb.add_output(y, "int8", (None, 8))
        m1 = gb.build()
        m2 = pqir.Model.from_json(json.loads(json.dumps(m1.to_json())))
        xq = rng.integers(-128, 128, (3, 16)).astype(np.int8)
        np.testing.assert_array_equal(
            ReferenceRuntime(m1).run({"x": xq})[y], ReferenceRuntime(m2).run({"x": xq})[y]
        )


class TestRandomGraphConformance:
    """Randomly composed PQ-IR graphs (mixed scalar/per-channel scales, mixed
    MatMulInteger/Gemm codification, random activations) must survive the
    full pass pipeline + ExecutionPlan lowering bit-exactly."""

    layer_st = st.fixed_dictionaries(
        {
            "per_channel": st.booleans(),
            "two_mul": st.booleans(),
            "gemm": st.booleans(),
            "trans_b": st.booleans(),
            "with_bias": st.booleans(),
            "activation": st.sampled_from([None, "Relu", "Tanh"]),
            "width": st.integers(min_value=1, max_value=48),
            # mixed-precision graphs: w4 and w8 layers coexist in one model
            "bits": st.sampled_from([4, 8]),
        }
    )

    @settings(deadline=None, max_examples=15)
    @given(
        layers=st.lists(layer_st, min_size=1, max_size=3),
        batch=st.integers(min_value=1, max_value=6),
        backend=st.sampled_from(["ref", "interpret"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_random_graph_pipeline_and_plan_match_reference(self, layers, batch, backend, seed):
        rng = np.random.default_rng(seed)
        gb = pqir.GraphBuilder("rand")
        n_in = int(rng.integers(1, 48))
        x = gb.add_input("x", "int8", (None, n_in))
        cur = n_in
        for i, cfg in enumerate(layers):
            w = rng.normal(size=(cur, cfg["width"])).astype(np.float32) * 0.2
            if cfg["per_channel"] and cfg["width"] > 1:
                w[:, int(rng.integers(0, cfg["width"]))] *= 20.0
            b = rng.normal(size=(cfg["width"],)).astype(np.float32) * 0.1 if cfg["with_bias"] else None
            if cfg["activation"] == "Tanh":
                p = quant.quantize_linear_layer(
                    w, b, 0.05, patterns.TANH_INPUT_ABSMAX / 127.0,
                    per_channel=cfg["per_channel"], bits=cfg["bits"],
                )
                x = patterns.fc_int8_tanh(gb, x, p, f"l{i}")
            else:
                p = quant.quantize_linear_layer(
                    w, b, 0.05, 0.1, per_channel=cfg["per_channel"], bits=cfg["bits"]
                )
                if cfg["gemm"]:
                    x = patterns.fc_layer_gemm(
                        gb, x, p, f"l{i}", two_mul=cfg["two_mul"],
                        activation=cfg["activation"], trans_b=cfg["trans_b"],
                    )
                else:
                    x = patterns.fc_layer(
                        gb, x, p, f"l{i}", two_mul=cfg["two_mul"], activation=cfg["activation"]
                    )
            cur = cfg["width"]
        gb.add_output(x, "int8", (None, cur))
        model = gb.build()
        feeds = {"x": rng.integers(-128, 128, (batch, n_in)).astype(np.int8)}
        ref = ReferenceRuntime(model).run(feeds)[x]
        cm = compile_model(model, backend=backend, verify_passes=True)
        assert cm.stats["generic"] == 0, cm.stats  # every layer fused
        got = cm.run(feeds)[x]
        np.testing.assert_array_equal(got, ref)


class TestKernelProperties:
    @settings(deadline=None, max_examples=12)
    @given(
        m=st.integers(min_value=1, max_value=40),
        k=st.integers(min_value=1, max_value=80),
        n=st.integers(min_value=1, max_value=40),
        in_dtype=st.sampled_from(["int8", "uint8"]),
        out_dtype=st.sampled_from(["int8", "uint8"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_wrapper_padding_exact(self, m, k, n, in_dtype, out_dtype, seed):
        import jax.numpy as jnp

        from repro.kernels import ops

        rng = np.random.default_rng(seed)
        lo, hi = (0, 256) if in_dtype == "uint8" else (-128, 128)
        x = rng.integers(lo, hi, (m, k)).astype(in_dtype)
        w = rng.integers(-128, 128, (k, n)).astype(np.int8)
        r = quant.decompose_multiplier(0.01)
        got = ops.quantized_matmul(
            jnp.asarray(x), jnp.asarray(w), None, float(r.quant_scale), r.quant_shift,
            out_dtype=jnp.int8 if out_dtype == "int8" else jnp.uint8,
            backend="interpret", bm=16, bk=32, bn=16,
        )
        acc = x.astype(np.int32) @ w.astype(np.int32)
        f = acc.astype(np.float32) * np.float32(r.quant_scale) * np.float32(r.quant_shift)
        info = np.iinfo(out_dtype)
        expect = np.clip(np.rint(f), info.min, info.max).astype(out_dtype)
        np.testing.assert_array_equal(np.asarray(got), expect)

    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_lut_covers_all_codes(self, seed):
        """LUT path equals the op-chain for every one of the 256 codes."""
        from repro.kernels.qact_lut import build_lut

        rng = np.random.default_rng(seed)
        in_s = float(rng.uniform(0.01, 0.1))
        out_s = float(rng.uniform(0.005, 0.02))
        lut = build_lut(np.tanh, in_s, out_s, "int8")
        codes = np.arange(-128, 128, dtype=np.int32)
        expect = np.clip(np.rint(np.tanh(codes * in_s) / out_s), -128, 127).astype(np.int8)
        np.testing.assert_array_equal(lut, expect)
