"""Observability plane: tracing round-trips, unified metrics, provenance.

Covers the repro.obs contract the rest of the stack leans on:

* trace export round-trip — nested spans land with correct depth, the
  Chrome-trace JSON loads back and is monotonic, async request pairs link;
* metrics snapshot determinism — concurrent publishers produce exact
  counts and byte-stable snapshots;
* no-tracer overhead — with nothing installed the instrumentation sites
  get one shared no-op span (no allocation, no recording);
* end-to-end — compile/serve with a tracer installed and find the pass,
  fusion, specialization and serving spans the ISSUE contract names.
"""
import json
import threading
import time

import numpy as np
import pytest

from repro.core.cache import LruCache
from repro.obs import trace as obs_trace
from repro.obs.metrics import Histogram, MetricsRegistry, cache_key
from repro.obs.provenance import PlanProvenance


@pytest.fixture
def tracer():
    t = obs_trace.install()
    try:
        yield t
    finally:
        obs_trace.uninstall()


def _mlp():
    from repro.core.toolchain import MLPSpec, quantize_mlp

    rng = np.random.default_rng(0)
    spec = MLPSpec(
        weights=[rng.normal(size=(32, 32)).astype(np.float32) * 0.1 for _ in range(2)],
        biases=[rng.normal(size=(32,)).astype(np.float32) * 0.1 for _ in range(2)],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(64, 32)).astype(np.float32)
    return quantize_mlp(spec, calib)


# -- tracing ------------------------------------------------------------------


class TestTracer:
    def test_nested_spans_record_depth_and_attrs(self, tracer):
        with obs_trace.span("outer", a=1):
            with obs_trace.span("inner") as s:
                s.set(tile="bm=32")
        outer, = tracer.spans("outer")
        inner, = tracer.spans("inner")
        assert outer.depth == 0 and inner.depth == 1
        assert outer.attrs == {"a": 1} and inner.attrs == {"tile": "bm=32"}
        # the child interval nests inside the parent interval
        assert outer.ts <= inner.ts
        assert inner.ts + inner.dur <= outer.ts + outer.dur + 1e-9

    def test_chrome_trace_json_round_trip(self, tracer):
        with obs_trace.span("compile.fuse", nodes=3):
            obs_trace.event("cache.plan.miss", key="8")
        obs_trace.async_begin("serve.request", 7, shape="(32,)")
        obs_trace.async_end("serve.request", 7)
        payload = json.loads(json.dumps(tracer.to_chrome_trace()))
        evs = payload["traceEvents"]
        assert evs[0]["ph"] == "M"  # process metadata
        body = evs[1:]
        # monotonic, non-negative microsecond timestamps
        ts = [e["ts"] for e in body]
        assert all(t >= 0 for t in ts) and ts == sorted(ts)
        by_ph = {e["ph"]: e for e in body}
        assert set(by_ph) == {"X", "i", "b", "e"}
        assert by_ph["X"]["name"] == "compile.fuse" and "dur" in by_ph["X"]
        assert by_ph["X"]["cat"] == "compile"
        assert by_ph["b"]["id"] == by_ph["e"]["id"] == 7
        assert payload["otherData"]["trace_id"] == tracer.trace_id

    def test_render_tree_nests(self, tracer):
        with obs_trace.span("outer"):
            with obs_trace.span("inner", k=2):
                pass
        tree = tracer.render_tree()
        assert tracer.trace_id in tree
        out_line, = [l for l in tree.splitlines() if "outer" in l]
        in_line, = [l for l in tree.splitlines() if "inner" in l]
        indent = lambda l: len(l) - len(l.lstrip())  # noqa: E731
        assert indent(in_line) > indent(out_line)
        assert "k=2" in in_line

    def test_exception_inside_span_still_records(self, tracer):
        with pytest.raises(RuntimeError):
            with obs_trace.span("boom"):
                raise RuntimeError("x")
        rec, = tracer.spans("boom")
        assert rec.attrs["error"] == "RuntimeError"

    def test_threads_get_distinct_tids(self, tracer):
        # barrier keeps all workers alive at once — otherwise the OS may
        # reuse a finished thread's ident and collapse tids
        barrier = threading.Barrier(3)

        def work():
            barrier.wait()
            with obs_trace.span("worker"):
                pass

        ts = [threading.Thread(target=work) for _ in range(3)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        with obs_trace.span("main"):
            pass
        tids = {r.tid for r in tracer.spans()}
        assert len(tids) == 4
        # every worker span is depth 0 in its own thread
        assert all(r.depth == 0 for r in tracer.spans("worker"))


class TestNoTracer:
    def test_span_is_shared_noop_singleton(self):
        assert obs_trace.current() is None and not obs_trace.enabled
        assert obs_trace.span("x", a=1) is obs_trace.span("y")
        assert obs_trace.span("x") is obs_trace.NULL_SPAN
        with obs_trace.span("x") as s:
            assert s.set(anything=1) is s
        obs_trace.event("x")  # no-ops, no error
        obs_trace.async_begin("x", 1)
        obs_trace.async_end("x", 1)

    def test_uninstrumented_overhead_smoke(self):
        """The no-tracer fast path is a global read + a shared singleton;
        generous bound, this guards against accidental allocation storms."""
        n = 20_000
        t0 = time.perf_counter()
        for _ in range(n):
            with obs_trace.span("hot"):
                pass
        dt = time.perf_counter() - t0
        assert dt < 1.0, f"{n} no-op spans took {dt:.3f}s"


# -- metrics ------------------------------------------------------------------


class TestMetrics:
    def test_concurrent_publish_is_exact_and_deterministic(self):
        reg = MetricsRegistry()
        n_threads, n_ops = 8, 1000

        def work(i):
            c = reg.counter("serve.requests")
            h = reg.histogram("serve.latency_ms")
            for k in range(n_ops):
                c.inc()
                h.observe((k % 17) + 0.5)

        ts = [threading.Thread(target=work, args=(i,)) for i in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        snap = reg.snapshot()
        assert snap["serve.requests"] == n_threads * n_ops
        assert snap["serve.latency_ms"]["count"] == n_threads * n_ops
        # deterministic: repeated snapshots of identical state are byte-equal
        assert json.dumps(snap) == json.dumps(reg.snapshot())
        json.loads(json.dumps(snap))  # JSON-able throughout

    def test_histogram_bounded_memory_and_quantiles(self):
        h = Histogram()
        for v in range(1, 10_001):
            h.observe(float(v))
        assert h.count == 10_000
        # log-bucketed: far fewer buckets than samples
        assert len(h.buckets) < 100
        assert h.quantile(0.0) == 1.0 and h.quantile(1.0) == 10_000.0
        # mid quantiles within the documented growth-factor error
        assert h.quantile(0.5) == pytest.approx(5000, rel=0.16)
        assert h.quantile(0.95) == pytest.approx(9500, rel=0.16)
        s = Histogram().stats()
        assert s["count"] == 0 and s["p99"] is None and s["avg"] is None

    def test_kind_mismatch_is_an_error(self):
        reg = MetricsRegistry()
        reg.counter("a.b")
        with pytest.raises(TypeError, match="a.b"):
            reg.gauge("a.b")

    def test_prometheus_export(self):
        reg = MetricsRegistry()
        reg.counter("serve.requests").inc(3)
        reg.gauge("cache.plan.size").set(2)
        reg.histogram("serve.latency_ms").observe(4.0)
        text = reg.to_prometheus()
        assert "# TYPE repro_serve_requests counter\nrepro_serve_requests 3" in text
        assert "repro_cache_plan_size 2" in text
        assert 'repro_serve_latency_ms{quantile="0.5"}' in text
        assert "repro_serve_latency_ms_count 1" in text

    def test_cache_attach_publishes_canonical_live_gauges(self):
        reg = MetricsRegistry()
        cache = LruCache(2, scope="plan")
        cache.attach_metrics(reg)
        cache.get("k")  # miss
        cache.put("k", 1)
        cache.get("k")  # hit
        snap = reg.snapshot()
        assert snap[cache_key("plan", "hits")] == 1.0
        assert snap[cache_key("plan", "misses")] == 1.0
        assert snap[cache_key("plan", "hit_rate")] == 0.5
        # live callback gauges: later cache activity shows without re-attach
        cache.get("k")
        assert reg.snapshot()[cache_key("plan", "hits")] == 2.0
        # the alias dict is untouched by the registry route
        assert set(cache.stats) == {"size", "capacity", "hits", "misses", "evictions", "hit_rate"}

    def test_scoped_cache_emits_trace_events(self, tracer):
        cache = LruCache(1, scope="plan")
        cache.get("a")
        cache.put("a", 1)
        cache.put("b", 2)  # evicts a
        cache.get("b")
        names = [e.name for e in tracer.events()]
        assert names.count("cache.plan.miss") == 1
        assert names.count("cache.plan.evict") == 1
        assert names.count("cache.plan.hit") == 1


# -- provenance ---------------------------------------------------------------


class TestProvenance:
    def test_record_and_render(self):
        p = PlanProvenance(nodes_before=10, nodes_after=7, pass_iterations=2)
        p.add_pass(0, "const_fold", {"folded": 3, "noise": 0})
        p.add_pass(0, "noop", {"x": 0})  # all-zero: not recorded
        p.add_fusion("qlinear", "fc0_matmul", ("fc0_matmul", "fc0_add"), "y")
        p.add_specialization({"N": 8}, {"fc0": "m=8,bm=32"})
        assert len(p.passes) == 1 and p.pass_totals == {"folded": 3}
        text = p.render()
        assert "passes: nodes 10->7 in 2 iteration(s) (folded=3)" in text
        assert "qlinear @ fc0_matmul: fc0_matmul+fc0_add -> y" in text
        assert "(N=8): fc0 m=8,bm=32" in text
        assert "trace" not in text  # only rendered when a tracer was installed
        d = json.loads(json.dumps(p.to_dict()))
        assert d["fusions"][0]["pattern"] == "qlinear"
        assert d["specializations"][0]["bindings"] == {"N": 8}

    def test_compiled_plan_carries_provenance(self):
        from repro.core.compile import compile_model

        cm = compile_model(_mlp(), backend="interpret", batch="dynamic")
        prov = cm.plan.provenance
        assert prov is not None
        assert len(prov.fusions) == cm.stats["fused_qlinear"] == 2
        assert prov.trace_id is None  # no tracer at compile time
        assert "provenance:" not in cm.plan.pretty()
        verbose = cm.plan.pretty(verbose=True)
        assert "provenance:" in verbose and "fusions: 2 matched" in verbose
        # lazy per-cell specialization appends to the shared record and the
        # specialized plan shows the same history
        x = np.zeros((3, 32), np.int8)
        cm.run({cm.input_names[0]: x})
        plan8, _ = cm.specialized(8)
        assert len(prov.specializations) == 2
        assert plan8.provenance is prov
        assert "specializations: 2" in cm.plan.pretty(verbose=True)


# -- end-to-end ---------------------------------------------------------------


class TestEndToEnd:
    def test_compile_and_serve_spans(self, tracer):
        from repro.core.compile import compile_model
        from repro.serving import CompiledModelServer, CompiledServerConfig

        cm = compile_model(_mlp(), backend="interpret", batch="dynamic")
        assert cm.plan.provenance.trace_id == tracer.trace_id
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
        rng = np.random.default_rng(1)
        reqs = [srv.submit(rng.integers(-128, 128, (32,)).astype(np.int8)) for _ in range(6)]
        srv.run_until_drained()
        assert all(r.done for r in reqs)

        assert tracer.spans("compile") and tracer.spans("compile.fuse")
        assert tracer.spans("compile.lower") and tracer.spans("passes.pipeline")
        assert any(s.name.startswith("pass.") for s in tracer.spans())
        # one specialization span per visited scenario cell (buckets 4 and 2)
        specs = tracer.spans("backend.specialize")
        assert len(specs) == 2
        assert {s.attrs["bindings"] for s in specs} == {"N=4", "N=2"}
        # each specialization span carries the chosen tiles per fused step
        assert all(
            any("bm=" in str(v) for v in s.attrs.values()) for s in specs
        )
        # serving: step spans with coalesce/compute children, request pairs
        steps = tracer.spans("serve.step")
        assert len(steps) == 2 and len(tracer.spans("serve.compute")) == 2
        recs = tracer.records
        begins = {r.aid for r in recs if r.kind == "async_b" and r.name == "serve.request"}
        ends = {r.aid for r in recs if r.kind == "async_e" and r.name == "serve.request"}
        assert begins == ends == {r.uid for r in reqs}
        # run phases inside the compiled model
        assert tracer.spans("run.pad") and tracer.spans("run.execute") and tracer.spans("run.slice")

    def test_server_registry_unifies_cache_and_serve_metrics(self):
        from repro.core.compile import compile_model
        from repro.serving import CompiledModelServer, CompiledServerConfig

        cm = compile_model(_mlp(), backend="interpret", batch="dynamic")
        reg = MetricsRegistry()
        srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4), registry=reg)
        rng = np.random.default_rng(2)
        for _ in range(5):
            srv.submit(rng.integers(-128, 128, (32,)).astype(np.int8))
        srv.run_until_drained()
        snap = reg.snapshot()
        assert snap["serve.requests"] == srv.metrics["requests"] == 5
        assert snap["serve.completed"] == 5
        assert snap["serve.latency_ms"]["count"] == 5
        assert snap["serve.queue_wait_ms"]["count"] == 5
        # canonical cache keys mirror the alias dict exactly
        for field, v in cm.cache_stats.items():
            assert snap[cache_key("plan", field)] == pytest.approx(float(v))
