"""E7: per-arch smoke tests — reduced same-family configs, one forward/train
step + one prefill/decode step on CPU; assert shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M

# heavyweight model/serving tier — excluded from the fast CI tier (scripts/check.sh)
pytestmark = pytest.mark.slow

B, S = 2, 16


def _batch(cfg, rng):
    tok = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}
    if cfg.frontend == "vision":
        n_txt = S - cfg.frontend_tokens
        batch["tokens"] = jnp.asarray(tok[:, :n_txt])
        batch["labels"] = jnp.asarray(tok[:, :n_txt])
        batch["patch_embeds"] = jnp.asarray(rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32))
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def setup(arch):
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, rng


class TestSmoke:
    def test_exact_full_config_dims(self, arch):
        """The full (non-reduced) config carries the exact published dims."""
        cfg = get_config(arch)
        expected = {
            "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
            "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
            "gemma2_2b": (26, 2304, 8, 4, 9216, 256000),
            "minicpm_2b": (40, 2304, 36, 36, 5760, 122753),
            "qwen3_1_7b": (28, 2048, 16, 8, 6144, 151936),
            "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
            "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
            "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
            "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
            "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        }[arch]
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
        assert got == expected, (arch, got, expected)

    def test_train_forward(self, setup):
        cfg, params, rng = setup
        batch = _batch(cfg, rng)
        loss, metrics = M.loss_fn(params, batch, cfg, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
        assert loss.shape == ()
        assert np.isfinite(float(loss)), float(loss)
        assert float(loss) > 0.0

    def test_train_grads_finite(self, setup):
        cfg, params, rng = setup
        batch = _batch(cfg, rng)
        g = jax.grad(lambda p: M.loss_fn(p, batch, cfg, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)[0])(params)
        flat, _ = jax.tree_util.tree_flatten(g)
        assert all(np.isfinite(np.asarray(x)).all() for x in flat)
        assert any(float(jnp.abs(x).max()) > 0 for x in flat)  # something learns

    def test_prefill_decode(self, setup):
        cfg, params, rng = setup
        batch = _batch(cfg, rng)
        max_len = S + 4
        cache = M.init_cache(cfg, B, max_len, src_len=S)
        logits, cache = M.prefill(params, batch, cfg, cache, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        pos0 = S - cfg.frontend_tokens if cfg.frontend == "vision" else S
        pos = jnp.full((B,), pos0, jnp.int32)
        if cfg.frontend == "vision":
            pos = jnp.full((B,), S, jnp.int32)  # absolute position incl. patches
        logits2, cache = M.decode_step(params, nxt, pos, cache, cfg, compute_dtype=jnp.float32)
        assert logits2.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits2)).all()

    def test_int8_kv_cache_close_to_bf16(self, setup):
        cfg, params, rng = setup
        if cfg.family == "rwkv6":
            pytest.skip("attention-free: no KV cache")
        batch = _batch(cfg, rng)
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        c16 = M.init_cache(cfg, B, S + 4, src_len=S)
        c8 = M.init_cache(cfg8, B, S + 4, src_len=S)
        l16, c16 = M.prefill(params, batch, cfg, c16, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
        l8, c8 = M.prefill(params, batch, cfg8, c8, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
        # prefill logits identical (cache quantization only affects decode reads)
        nxt = jnp.argmax(l16, axis=-1)[:, None].astype(jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        d16, _ = M.decode_step(params, nxt, pos, c16, cfg, compute_dtype=jnp.float32)
        d8, _ = M.decode_step(params, nxt, pos, c8, cfg8, compute_dtype=jnp.float32)
        # int8 KV cache should track bf16 within a loose logit tolerance
        denom = float(jnp.abs(d16).max()) + 1e-6
        rel = float(jnp.abs(d8 - d16).max()) / denom
        assert rel < 0.25, rel
