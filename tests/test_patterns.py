"""E2: Figs 1–6 conformance — build each pattern, execute in the reference
runtime (ONNXRuntime stand-in), check semantics + paper goals 1–4."""
import json

import numpy as np
import pytest

from repro.core import patterns, pqir, quant
from repro.core.runtime import ReferenceRuntime


def _mk_fc(rng, n_in=64, n_out=32, scale_y=0.1):
    x = rng.normal(size=(8, n_in)).astype(np.float32)
    w = rng.normal(size=(n_in, n_out)).astype(np.float32) * 0.1
    b = rng.normal(size=(n_out,)).astype(np.float32) * 0.2
    scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
    p = quant.quantize_linear_layer(w, b, scale_x, scale_y)
    xq = quant.quantize(x, scale_x, "int8")
    return x, w, b, p, xq, scale_x


class TestFig1FCTwoMul:
    def test_structure_and_execution(self):
        rng = np.random.default_rng(0)
        _, _, _, p, xq, _ = _mk_fc(rng)
        gb = pqir.GraphBuilder("fig1")
        x = gb.add_input("input_q", "int8", (None, 64))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True)
        gb.add_output(y, "int8", (None, 32))
        model = gb.build()

        # structure: exactly the Fig.1 op sequence
        ops = [n.op_type for n in model.graph.toposorted()]
        assert ops == ["MatMulInteger", "Add", "Cast", "Mul", "Mul", "QuantizeLinear"]

        out = ReferenceRuntime(model).run({"input_q": xq})[y]
        np.testing.assert_array_equal(out, quant.fc_reference(xq, p, two_mul=True))

    def test_goal1_params_embedded(self):
        """Paper goal 1: quantization params embedded as initializers —
        quant_scale is an *integer stored as FLOAT*."""
        rng = np.random.default_rng(0)
        _, _, _, p, _, _ = _mk_fc(rng)
        gb = pqir.GraphBuilder("fig1")
        x = gb.add_input("input_q", "int8", (None, 64))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True)
        gb.add_output(y, "int8", (None, 32))
        model = gb.build()
        init = model.graph.initializers
        qs = init["fc0_quant_scale"]
        assert qs.dtype == np.float32 and float(qs) == int(float(qs))  # integer as FLOAT
        assert float(init["fc0_quant_shift"]) == 2.0**-p.rescale.shift
        assert init["fc0_weight_q"].dtype == np.int8
        assert init["fc0_bias_q"].dtype == np.int32

    def test_goal3_standard_ops_only(self):
        rng = np.random.default_rng(0)
        _, _, _, p, _, _ = _mk_fc(rng)
        gb = pqir.GraphBuilder("fig1")
        x = gb.add_input("input_q", "int8", (None, 64))
        y = patterns.fc_layer(gb, x, p, "fc0")
        gb.add_output(y, "int8", (None, 32))
        model = gb.build()
        model.validate(standard_ops_only=True)  # raises on custom ops
        # and the validator does reject custom ops:
        bad = pqir.Node("MyCustomRescale", ["a"], ["b"])
        model.graph.nodes.append(bad)
        with pytest.raises(ValueError, match="non-standard"):
            model.validate()

    def test_serialization_roundtrip(self):
        rng = np.random.default_rng(0)
        _, _, _, p, xq, _ = _mk_fc(rng)
        gb = pqir.GraphBuilder("fig1")
        x = gb.add_input("input_q", "int8", (None, 64))
        y = patterns.fc_layer(gb, x, p, "fc0")
        gb.add_output(y, "int8", (None, 32))
        model = gb.build()
        blob = json.dumps(model.to_json())
        model2 = pqir.Model.from_json(json.loads(blob))
        out1 = ReferenceRuntime(model).run({"input_q": xq})[y]
        out2 = ReferenceRuntime(model2).run({"input_q": xq})[y]
        np.testing.assert_array_equal(out1, out2)


class TestFig2FCRelu:
    def test_structure_and_relu_semantics(self):
        rng = np.random.default_rng(1)
        x_f, w, b, p, xq, scale_x = _mk_fc(rng)
        gb = pqir.GraphBuilder("fig2")
        x = gb.add_input("input_q", "int8", (None, 64))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=False, activation="Relu")
        gb.add_output(y, "int8", (None, 32))
        model = gb.build()
        ops = [n.op_type for n in model.graph.toposorted()]
        assert ops == ["MatMulInteger", "Add", "Cast", "Mul", "Relu", "QuantizeLinear"]
        out = ReferenceRuntime(model).run({"input_q": xq})[y]
        assert out.min() >= 0
        # ReLU(rescale(acc)) == rescale(acc) clipped at 0
        base = quant.fc_reference(xq, p, two_mul=False)
        np.testing.assert_array_equal(out, np.maximum(base, 0))


class TestFig3Conv:
    def test_conv_pattern(self):
        rng = np.random.default_rng(2)
        x = rng.normal(size=(2, 3, 12, 12)).astype(np.float32)
        w = rng.normal(size=(8, 3, 3, 3)).astype(np.float32) * 0.2
        b = rng.normal(size=(8,)).astype(np.float32) * 0.1
        scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
        scale_w = quant.choose_scale(float(np.abs(w).max()), "int8")
        wq = quant.quantize(w, scale_w, "int8")
        xq = quant.quantize(x, scale_x, "int8")
        bq = quant.quantize_bias(b, scale_w, scale_x)
        scale_y = 0.05
        rescale = quant.decompose_multiplier(scale_w * scale_x / scale_y)

        gb = pqir.GraphBuilder("fig3")
        xi = gb.add_input("input_q", "int8", (None, 3, 12, 12))
        y = patterns.conv_layer(gb, xi, wq, bq, rescale, "conv0", pads=(1, 1, 1, 1))
        gb.add_output(y, "int8", (None, 8, 12, 12))
        model = gb.build()
        ops = [n.op_type for n in model.graph.toposorted()]
        assert ops == ["ConvInteger", "Add", "Cast", "Mul", "QuantizeLinear"]

        out = ReferenceRuntime(model).run({"input_q": xq})[y]
        assert out.shape == (2, 8, 12, 12) and out.dtype == np.int8
        # compare against float conv within quantization error
        from repro.core.runtime import _conv2d_f32

        ref = _conv2d_f32(x, w, {"pads": (1, 1, 1, 1)}) + b.reshape(1, -1, 1, 1)
        y_hat = out.astype(np.float32) * scale_y
        rel = np.abs(y_hat - ref).max() / np.abs(ref).max()
        assert rel < 0.06, rel


class TestFig456Activations:
    def _build(self, fn, rng_seed, **kw):
        rng = np.random.default_rng(rng_seed)
        x = rng.normal(size=(8, 32)).astype(np.float32)
        w = rng.normal(size=(32, 16)).astype(np.float32) * 0.3
        b = rng.normal(size=(16,)).astype(np.float32) * 0.1
        scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
        absmax = kw.get("input_absmax", patterns.TANH_INPUT_ABSMAX)
        p = quant.quantize_linear_layer(w, b, scale_x, absmax / 127.0)
        xq = quant.quantize(x, scale_x, "int8")
        gb = pqir.GraphBuilder("figact")
        xi = gb.add_input("input_q", "int8", (None, 32))
        y = fn(gb, xi, p, "fc0", **kw)
        out_dtype = "uint8" if fn is patterns.fc_fp16_sigmoid else "int8"
        gb.add_output(y, out_dtype, (None, 16))
        return gb.build(), xq, x, w, b, y

    def test_fig4_int8_tanh(self):
        model, xq, x, w, b, yname = self._build(patterns.fc_int8_tanh, 3)
        ops = [n.op_type for n in model.graph.toposorted()]
        assert ops == [
            "MatMulInteger", "Add", "Cast", "Mul", "Mul", "QuantizeLinear",
            "DequantizeLinear", "Tanh", "QuantizeLinear",
        ]
        out = ReferenceRuntime(model).run({"input_q": xq})[yname]
        assert out.dtype == np.int8
        ref = np.tanh(x @ w + b)
        y_hat = out.astype(np.float32) / 127.0
        assert np.abs(y_hat - ref).max() < 0.06  # int8 tanh approximation

    def test_fig5_fp16_tanh(self):
        model, xq, x, w, b, yname = self._build(patterns.fc_fp16_tanh, 4)
        ops = [n.op_type for n in model.graph.toposorted()]
        assert ops == [
            "MatMulInteger", "Add", "Cast", "Mul", "Mul", "QuantizeLinear",
            "DequantizeLinear", "Cast", "Tanh", "Cast", "QuantizeLinear",
        ]
        # the fp16 section really is fp16 in the reference runtime
        out = ReferenceRuntime(model).run({"input_q": xq})[yname]
        ref = np.tanh(x @ w + b)
        assert np.abs(out.astype(np.float32) / 127.0 - ref).max() < 0.06

    def test_fig6_fp16_sigmoid_uint8(self):
        model, xq, x, w, b, yname = self._build(
            patterns.fc_fp16_sigmoid, 5, input_absmax=patterns.SIGMOID_INPUT_ABSMAX
        )
        ops = [n.op_type for n in model.graph.toposorted()]
        assert ops == [
            "MatMulInteger", "Add", "Cast", "Mul", "QuantizeLinear",
            "DequantizeLinear", "Cast", "Sigmoid", "Cast", "QuantizeLinear",
        ]
        out = ReferenceRuntime(model).run({"input_q": xq})[yname]
        assert out.dtype == np.uint8  # paper: sigmoid output is always positive
        ref = 1.0 / (1.0 + np.exp(-(x @ w + b)))
        assert np.abs(out.astype(np.float32) / 255.0 - ref).max() < 0.05


class TestToolchainEndToEnd:
    def test_quantize_mlp_artifact(self):
        from repro.core.toolchain import MLPSpec, quantize_mlp

        rng = np.random.default_rng(7)
        spec = MLPSpec(
            weights=[rng.normal(size=(32, 64)).astype(np.float32) * 0.2,
                     rng.normal(size=(64, 10)).astype(np.float32) * 0.2],
            biases=[rng.normal(size=(64,)).astype(np.float32) * 0.1,
                    rng.normal(size=(10,)).astype(np.float32) * 0.1],
            activations=["Relu", None],
        )
        calib = rng.normal(size=(256, 32)).astype(np.float32)
        model = quantize_mlp(spec, calib)
        model.validate(standard_ops_only=True)

        x = rng.normal(size=(16, 32)).astype(np.float32)
        s_in = eval(model.metadata["input_scale"])
        s_out = eval(model.metadata["output_scale"])
        xq = quant.quantize(x, s_in, "int8")
        out = ReferenceRuntime(model).run({"input_q": xq})
        (yq,) = out.values()
        ref = np.maximum(x @ spec.weights[0] + spec.biases[0], 0) @ spec.weights[1] + spec.biases[1]
        y_hat = yq.astype(np.float32) * s_out
        rel = np.abs(y_hat - ref).max() / np.abs(ref).max()
        assert rel < 0.1, rel

    def test_quantize_cnn_artifact(self):
        from repro.core.toolchain import CNNSpec, ConvLayerSpec, MLPSpec, quantize_cnn

        rng = np.random.default_rng(8)
        spec = CNNSpec(
            convs=[
                ConvLayerSpec(rng.normal(size=(4, 1, 3, 3)).astype(np.float32) * 0.3,
                              rng.normal(size=(4,)).astype(np.float32) * 0.1,
                              activation="Relu"),
            ],
            head=MLPSpec(
                weights=[rng.normal(size=(4 * 6 * 6, 10)).astype(np.float32) * 0.1],
                biases=[rng.normal(size=(10,)).astype(np.float32) * 0.1],
                activations=[None],
            ),
        )
        calib = rng.normal(size=(64, 1, 8, 8)).astype(np.float32)
        model = quantize_cnn(spec, calib)
        model.validate(standard_ops_only=True)
        s_in = eval(model.metadata["input_scale"])
        xq = quant.quantize(calib[:4], s_in, "int8")
        out = ReferenceRuntime(model).run({"input_q": xq})
        (yq,) = out.values()
        assert yq.shape == (4, 10)
