"""Token-path differential suite: the codified transformer block (PR 10).

Pins, bit-for-bit, the three runtimes of the prefill/decode pair against each
other over a (batch × prompt-len) grid:

  numpy ReferenceRuntime == compiled ref backend == compiled interpret backend
  == the jnp mirrors (prefill_jax / decode_jax)

with int8 KV-cache state slots and mixed w4/w8 projection weights, plus unit
coverage for the state machinery (StateSpec round-trip, pinned plan slots,
per-bucket seq-extent binding, artifact round-trip, plan_diff state records,
shared-PlanCache one-specialization-per-cell) and the fused attention lane
(matcher, kernel, autotuner branch).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.backend.artifact import load_artifact, save_artifact
from repro.backend.autotune import Autotuner, attention_candidates, is_attention_shape
from repro.backend.plan import PlanCache
from repro.core import pqir
from repro.core.compile import compile_model
from repro.core.patterns import build_exp_lut, emit_qattention
from repro.core.runtime import ReferenceRuntime
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.serving.token_path import (
    CompiledTokenAdapter,
    CompiledTokenPath,
    TokenPathConfig,
    build_decode_model,
    decode_jax,
    make_token_params,
    prefill_jax,
)

CFG = TokenPathConfig()  # defaults: mixed w4 (qkv, down) / w8 (o, up)
PARAMS = make_token_params(CFG, seed=3)


def _causal(n, s):
    return np.broadcast_to(np.tril(np.ones((s, s), np.float32)), (n, s, s)).copy()


def _tokens(rng, n, s):
    return rng.integers(1, CFG.vocab, (n, s)).astype(np.int32)


def _tp(backend="ref", **kw):
    kw.setdefault("s_granularity", 8)
    return CompiledTokenPath(CFG, PARAMS, backend=backend, **kw)


def _states_list(tp, cache):
    return [
        (cache[tp.state_specs[2 * l].input], cache[tp.state_specs[2 * l + 1].input])
        for l in range(tp.cfg.n_layers)
    ]


class TestDifferentialSweep:
    """Compiled prefill+decode bit-exact vs the jnp mirror over a grid."""

    @pytest.mark.parametrize("backend", ["ref", "interpret"])
    @pytest.mark.parametrize("n,plen", [(1, 3), (2, 7), (3, 8), (2, 12)])
    def test_prefill_grid(self, backend, n, plen):
        tp = _tp(backend)
        rng = np.random.default_rng(100 * n + plen)
        toks = _tokens(rng, n, plen)
        mask = _causal(n, plen)
        logits, cache = tp.prefill(toks, mask)
        jl, jcaches = prefill_jax(CFG, PARAMS, toks, mask)
        np.testing.assert_array_equal(logits, np.asarray(jl))
        for (k_j, v_j), (k_c, v_c) in zip(jcaches, _states_list(tp, cache)):
            np.testing.assert_array_equal(k_c, np.asarray(k_j))
            np.testing.assert_array_equal(v_c, np.asarray(v_j))

    @pytest.mark.parametrize("backend", ["ref", "interpret"])
    @pytest.mark.parametrize("n,plen", [(1, 3), (2, 5)])
    def test_decode_steps_follow_prefill(self, backend, n, plen):
        tp = _tp(backend)
        rng = np.random.default_rng(7 * n + plen)
        s_max = 16
        toks = _tokens(rng, n, plen)
        _, pcache = tp.prefill(toks, _causal(n, plen))
        cache = tp.init_cache(n, s_max)
        for k in cache:
            cache[k][:, :plen] = pcache[k][:, :plen]
        jstates = _states_list(tp, {k: v.copy() for k, v in cache.items()})
        for step in range(3):
            pos = plen + step
            tok = _tokens(rng, n, 1)
            onehot = np.zeros((n, s_max, 1), np.int8)
            onehot[:, pos, 0] = 1
            mask = np.broadcast_to(
                (np.arange(s_max)[None, None, :] <= pos), (n, 1, s_max)
            ).astype(np.float32)
            logits, cache = tp.decode(tok, onehot, mask, cache)
            jl, jstates = decode_jax(CFG, PARAMS, tok, onehot, mask, jstates)
            np.testing.assert_array_equal(logits, np.asarray(jl))
            for (k_j, v_j), (k_c, v_c) in zip(jstates, _states_list(tp, cache)):
                np.testing.assert_array_equal(k_c, np.asarray(k_j))
                np.testing.assert_array_equal(v_c, np.asarray(v_j))

    def test_prefill_matches_numpy_runtime(self):
        tp = _tp("ref")
        rng = np.random.default_rng(0)
        toks = _tokens(rng, 2, 6)
        mask = _causal(2, 6)
        logits, _ = tp.prefill(toks, mask)
        want = ReferenceRuntime(tp.prefill_model).run({"tokens": toks, "mask": mask})
        np.testing.assert_array_equal(
            logits, want[tp.prefill_model.graph.outputs[0].name]
        )

    def test_decode_matches_numpy_runtime(self):
        tp = _tp("ref")
        rng = np.random.default_rng(1)
        n, s = 2, 8
        cache = tp.init_cache(n, s)
        tok = _tokens(rng, n, 1)
        onehot = np.zeros((n, s, 1), np.int8)
        onehot[:, 0, 0] = 1
        mask = np.broadcast_to(
            (np.arange(s)[None, None, :] <= 0), (n, 1, s)
        ).astype(np.float32)
        logits, _ = tp.decode(tok, onehot, mask, cache)
        want = ReferenceRuntime(tp.decode_model).run(
            {"tokens": tok, "onehot": onehot, "mask": mask, **cache}
        )
        np.testing.assert_array_equal(
            logits, want[tp.decode_model.graph.outputs[0].name]
        )

    def test_mixed_bitwidths_render_in_plan(self):
        tp = _tp("ref")
        pretty = tp.decode_cm.plan.pretty()
        assert "weight_bits=4" in pretty  # qkv / down projections
        assert tp.decode_cm.stats["fused_qlinear"] == 4 * CFG.n_layers
        assert tp.decode_cm.stats["fused_qattention"] == CFG.n_heads * CFG.n_layers


class TestStateSpecs:
    def test_round_trip_and_validation(self):
        m = build_decode_model(CFG, PARAMS)
        doc = m.to_json()
        m2 = pqir.Model.from_json(doc)
        m2.validate()
        assert [s.name for s in m2.graph.states] == [s.name for s in m.graph.states]
        assert all(
            (s.input, s.output) == (t.input, t.output)
            for s, t in zip(m2.graph.states, m.graph.states)
        )

    def test_stateless_json_unchanged(self):
        gb = pqir.GraphBuilder("plain")
        gb.add_input("x", "int8", (2, 4))
        y = gb.op("Relu", ["x"], out_hint="y")
        gb.add_output(y, "int8", (2, 4))
        doc = gb.build(opset=17).to_json()
        assert "states" not in doc["graph"]

    def test_duplicate_state_name_rejected(self):
        gb = pqir.GraphBuilder("dup")
        gb.add_input("a", "int8", (2, 4))
        gb.add_input("b", "int8", (2, 4))
        ya = gb.op("Relu", ["a"], out_hint="ya")
        yb = gb.op("Relu", ["b"], out_hint="yb")
        gb.add_output(ya, "int8", (2, 4))
        gb.add_output(yb, "int8", (2, 4))
        gb.add_state("s", input="a", output=ya)
        gb.add_state("s", input="b", output=yb)
        with pytest.raises(ValueError, match="state"):
            gb.build(opset=17)


class TestPlanStates:
    def test_pinned_slots_and_seq_binding(self):
        tp = _tp("ref")
        plan = tp.decode_cm.plan
        assert len(plan.states) == 2 * CFG.n_layers
        for sb in plan.states:
            assert sb.dtype == "int8"
            assert sb.shape == ("N", "S", CFG.d_model)
        # state input slots are pinned and mutually distinct
        in_slots = [sb.in_slot for sb in plan.states]
        assert len(set(in_slots)) == len(in_slots)
        assert "states:" in plan.pretty()
        # per-bucket specialization binds the seq extent
        spec, _ = tp.decode_cm.specialized({"N": 2, "S": 16})
        for sb in spec.states:
            assert sb.shape == (2, 16, CFG.d_model)

    def test_next_state_feeds(self):
        tp = _tp("ref")
        plan = tp.decode_cm.plan
        outs = {sb.output: f"v{i}" for i, sb in enumerate(plan.states)}
        feeds = plan.next_state_feeds(outs)
        assert feeds == {sb.input: f"v{i}" for i, sb in enumerate(plan.states)}


class TestArtifactStates:
    def test_states_round_trip(self, tmp_path):
        tp = _tp("ref")
        n, s = 1, 8
        cache = tp.init_cache(n, s)
        tok = np.ones((n, 1), np.int32)
        onehot = np.zeros((n, s, 1), np.int8)
        onehot[:, 0, 0] = 1
        mask = (np.arange(s)[None, None, :] <= 0).astype(np.float32)
        logits, _ = tp.decode(tok, onehot, mask, cache)
        path = str(tmp_path / "decode.json")
        save_artifact(tp.decode_cm, path)
        doc = json.load(open(path))
        assert len(doc["plan"]["states"]) == 2 * CFG.n_layers
        cm2 = load_artifact(path)
        assert [sb.name for sb in cm2.plan.states] == [
            sb.name for sb in tp.decode_cm.plan.states
        ]
        got = cm2.run({"tokens": tok, "onehot": onehot, "mask": mask, **cache})
        np.testing.assert_array_equal(
            logits, np.asarray(got[tp.decode_model.graph.outputs[0].name])
        )
        # the pre-seeded cell serves without a new specialization
        assert cm2.plan_cache.stats["misses"] == 0


class TestPlanDiffStates:
    def test_stateful_never_diffs_clean_vs_stateless(self, tmp_path):
        tp = _tp("ref")
        a = str(tmp_path / "prefill.json")
        b = str(tmp_path / "decode.json")
        save_artifact(tp.prefill_cm, a)
        save_artifact(tp.decode_cm, b)
        script = os.path.join(os.path.dirname(__file__), "..", "scripts", "plan_diff.py")
        r = subprocess.run(
            [sys.executable, script, a, b], capture_output=True, text=True
        )
        assert r.returncode == 1
        assert "state slots" in r.stdout
        assert "kv0_k" in r.stdout

    def test_same_plan_diffs_clean(self, tmp_path):
        tp = _tp("ref")
        a = str(tmp_path / "a.json")
        save_artifact(tp.decode_cm, a)
        script = os.path.join(os.path.dirname(__file__), "..", "scripts", "plan_diff.py")
        r = subprocess.run(
            [sys.executable, script, a, a], capture_output=True, text=True
        )
        assert r.returncode == 0, r.stdout


class TestSharedCacheServing:
    def test_one_specialization_per_visited_cell(self):
        tp = _tp("ref")
        eng = ServeEngine(
            ecfg=EngineConfig(slots=2, max_len=16, prefill_bucket=8),
            adapter=CompiledTokenAdapter(tp),
        )
        rng = np.random.default_rng(5)
        for i in range(4):
            eng.submit(
                Request(
                    uid=i,
                    prompt=rng.integers(1, CFG.vocab, (int(rng.integers(2, 8)),)).astype(np.int32),
                    max_new_tokens=4,
                )
            )
        eng.run_until_drained()
        stats = tp.cache_stats()
        # one prefill cell (N=1, S=8) + one decode cell (N=2, S=16): every
        # other prefill/decode step is a cache hit — zero re-lowering
        assert stats["misses"] == 2
        assert stats["size"] == 2
        assert stats["hits"] == eng.metrics["prefills"] + eng.metrics["decode_steps"] - 2
        assert all(r.done for r in eng.active.values()) or not eng.active

    def test_engine_matches_mirror_generation(self):
        """Greedy generation through the engine == hand-rolled jnp-mirror loop."""
        tp = _tp("ref")
        eng = ServeEngine(
            ecfg=EngineConfig(slots=1, max_len=16, prefill_bucket=8),
            adapter=CompiledTokenAdapter(tp),
        )
        prompt = np.array([5, 9, 2], np.int32)
        req = Request(uid=0, prompt=prompt, max_new_tokens=4)
        eng.submit(req)
        eng.run_until_drained()

        # mirror: prefill at the bucket length, then decode token by token
        bucket, s_max, plen = 8, 16, len(prompt)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = prompt
        jl, jcaches = prefill_jax(CFG, PARAMS, padded, _causal(1, bucket))
        states = []
        for k, v in jcaches:
            ks = np.zeros((1, s_max, CFG.d_model), np.int8)
            vs = np.zeros((1, s_max, CFG.d_model), np.int8)
            ks[:, :bucket] = np.asarray(k)
            vs[:, :bucket] = np.asarray(v)
            states.append((ks, vs))
        toks = [int(np.asarray(jl)[0, plen - 1].argmax())]
        pos = plen
        for _ in range(3):
            onehot = np.zeros((1, s_max, 1), np.int8)
            onehot[0, pos, 0] = 1
            mask = (np.arange(s_max)[None, None, :] <= pos).astype(np.float32)
            jl, states = decode_jax(
                CFG, PARAMS, np.array([[toks[-1]]], np.int32), onehot, mask, states
            )
            toks.append(int(np.asarray(jl)[0, 0].argmax()))
            pos += 1
        assert req.generated == toks


class TestAttentionLane:
    def test_matcher_counts_regions(self):
        tp = _tp("ref")
        assert tp.prefill_cm.stats["fused_qattention"] == CFG.n_heads * CFG.n_layers

    def test_single_region_interpret_matches_ref(self):
        gb = pqir.GraphBuilder("attn_one")
        gb.add_input("q", "int8", ("N", "S", 32))
        gb.add_input("k", "int8", ("N", "S", 32))
        gb.add_input("v", "int8", ("N", "S", 32))
        gb.add_input("mask", "float32", ("N", "S", "S"))
        out = emit_qattention(gb, "q", "k", "v", "mask", "a0", qk_scale=0.01, rescale=0.02)
        gb.add_output(out, "int8", ("N", "S", 32))
        m = gb.build(opset=17)
        rng = np.random.default_rng(0)
        feeds = {
            "q": rng.integers(-128, 128, (2, 7, 32)).astype(np.int8),
            "k": rng.integers(-128, 128, (2, 7, 32)).astype(np.int8),
            "v": rng.integers(-128, 128, (2, 7, 32)).astype(np.int8),
            "mask": _causal(2, 7),
        }
        dyn = {"N": None, "S": None}
        ref = compile_model(m, backend="ref", batch="dynamic", dynamic_axes=dyn)
        itp = compile_model(m, backend="interpret", batch="dynamic", dynamic_axes=dyn)
        assert ref.stats["fused_qattention"] == 1
        want = ReferenceRuntime(m).run(feeds)
        for cm in (ref, itp):
            got = cm.run(feeds)
            for kk in want:
                np.testing.assert_array_equal(np.asarray(got[kk]), want[kk])

    def test_exp_lut_zero_floor(self):
        lut = build_exp_lut()
        assert lut.shape == (256,)
        assert lut[0] == 0  # padding exactness hinges on this
        assert lut[128] == 255  # exp(0) at full scale


class TestAutotuneAttention:
    def test_shape_predicate(self):
        assert is_attention_shape({"b": 2, "s": 8, "t": 8, "dh": 32, "bq": 32})
        assert not is_attention_shape({"m": 8, "k": 16, "n": 32})

    def test_candidates_respect_alignment(self):
        cands = attention_candidates(100, 128, 32)
        assert all(bq % 32 == 0 for bq in cands)
        assert all(bq <= 128 for bq in cands)  # never exceeds rounded-up S
        assert len(cands) >= 2  # a real lattice to search

    def test_measured_search_tags_tuned(self):
        gb = pqir.GraphBuilder("attn_tuned")
        gb.add_input("q", "int8", ("N", "S", 32))
        gb.add_input("k", "int8", ("N", "S", 32))
        gb.add_input("v", "int8", ("N", "S", 32))
        gb.add_input("mask", "float32", ("N", "S", "S"))
        out = emit_qattention(gb, "q", "k", "v", "mask", "a0", qk_scale=0.01, rescale=0.02)
        gb.add_output(out, "int8", ("N", "S", 32))
        m = gb.build(opset=17)
        calls = []

        def measure(fn, *a, **kw):
            calls.append(1)
            return float(len(calls))  # first candidate (the heuristic) wins

        cm = compile_model(
            m, backend="interpret", batch="dynamic",
            dynamic_axes={"N": None, "S": None}, autotune=Autotuner(measure_fn=measure),
        )
        spec, _ = cm.specialized({"N": 2, "S": 100})
        assert len(calls) >= 2
        assert "bq" in spec.steps[0].params["shape"]
        recs = [
            rec
            for ev in cm.plan.provenance.specializations
            for _, rec in ev.tiles
        ]
        assert any("[tuned]" in r for r in recs)
