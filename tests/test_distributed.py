"""Distributed behaviour on 8 forced host devices (subprocess-isolated so the
rest of the suite keeps a single device).

Covers: logical sharding rules + divisibility fallback, sharded train step on
a (2,2)=(data,model) mesh matching single-device numerics, int8 gradient
compression over a 'pod' axis (error feedback convergence), and elastic
checkpoint restore onto a different mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# heavyweight model/serving tier — excluded from the fast CI tier (scripts/check.sh)
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    return out.stdout


class TestShardingRules:
    def test_divisibility_fallback(self):
        out = run_py("""
            import jax, json
            from repro.distributed.sharding import spec_for, use_mesh
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            with use_mesh(mesh):
                ok = spec_for((16, 32), ("embed", "heads"))      # both divide
                fb = spec_for((16, 6), ("embed", "heads"))       # 6 % 4 != 0 -> fallback
                b  = spec_for((8, 128), ("batch", None))
            print(json.dumps({"ok": str(ok), "fb": str(fb), "b": str(b)}))
        """)
        d = json.loads(out.strip().splitlines()[-1])
        assert "data" in d["ok"] and "model" in d["ok"]
        assert "model" not in d["fb"]
        assert "data" in d["b"]

    def test_multipod_batch_spans_pod_and_data(self):
        out = run_py("""
            import jax, json
            from repro.distributed.sharding import spec_for, use_mesh
            mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            with use_mesh(mesh):
                s = spec_for((8, 64), ("batch", None))
            print(str(s))
        """)
        assert "pod" in out and "data" in out


class TestShardedTrainStep:
    def test_matches_single_device(self):
        """One train step on a (2,2) mesh == same step on 1 device (f32)."""
        code = """
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.configs.base import ShapeConfig
            from repro.distributed.sharding import use_mesh
            from repro.launch import steps as S, specs as SP
            from repro.models import model as M
            from repro.optim import adamw
            from repro.data.pipeline import Pipeline, DataConfig

            cfg = get_config("qwen3_1_7b", reduced=True)
            sc = ShapeConfig("t", "train", 32, 4, microbatches=2)
            step = S.make_train_step(cfg, sc, compute_dtype=jnp.float32, q_chunk=16, kv_chunk=16)
            batch = {k: jnp.asarray(v) for k, v in Pipeline(cfg, DataConfig(0)).batch(0, 4, 32).items()}
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            opt = adamw.init(params)

            p1, o1, m1 = jax.jit(step)(params, opt, batch)   # single device

            mesh = jax.make_mesh((2, 2), ("data", "model"))
            with use_mesh(mesh):
                p_sh = SP.params_shardings(jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg)), mesh)
                o_sh = {"m": p_sh, "v": p_sh, "step": None}
                b_sh = SP.batch_shardings({k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}, mesh)
                params_d = jax.device_put(params, p_sh)
                opt_d = jax.device_put(opt, {"m": p_sh, "v": p_sh, "step": None}["m"] if False else jax.tree.map(lambda s: s, {"m": p_sh, "v": p_sh, "step": None}))
                opt_d = {"m": jax.device_put(opt["m"], p_sh), "v": jax.device_put(opt["v"], p_sh), "step": opt["step"]}
                batch_d = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
                p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, {"m": p_sh, "v": p_sh, "step": None}, b_sh))(params_d, opt_d, batch_d)

            print("LOSS", float(m1["loss"]), float(m2["loss"]))
            d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
            mx = max(jax.tree.leaves(d))
            print("MAXDIFF", mx)
            assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
            assert mx < 2e-4, mx
        """
        out = run_py(code)
        assert "MAXDIFF" in out


class TestGradCompression:
    def test_int8_allreduce_error_feedback(self):
        """Compressed cross-pod mean ≈ true mean; error feedback drives the
        accumulated bias to ~0 over repeated steps on a persistent gradient."""
        code = """
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import Mesh, PartitionSpec as P
            from jax.experimental.shard_map import shard_map
            from repro.optim.grad_compress import compressed_cross_pod_mean, init_residuals

            mesh = jax.make_mesh((8,), ("pod",))
            g_global = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32))
            true_mean = g_global.mean(axis=0)

            @jax.jit
            def one_round(g, res):
                def body(g_l, r_l):
                    gm, r2 = compressed_cross_pod_mean({"w": g_l[0]}, {"w": r_l[0]}, axis="pod")
                    return gm["w"][None], r2["w"][None]
                return shard_map(body, mesh=mesh,
                                 in_specs=(P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")))(g, res)

            res = jnp.zeros((8, 64), jnp.float32)
            total_true = jnp.zeros((64,))
            total_comp = jnp.zeros((64,))
            for step in range(50):
                gm, res = one_round(g_global, res)
                total_comp = total_comp + gm[0]
                total_true = total_true + true_mean
            one_err = float(jnp.abs(gm[0] - true_mean).max() / jnp.abs(true_mean).max())
            cum_err = float(jnp.abs(total_comp - total_true).max() / jnp.abs(total_true).max())
            print("ONE", one_err, "CUM", cum_err)
            assert one_err < 0.05            # single round: int8-accurate
            assert cum_err < 0.005           # error feedback kills the bias
        """
        out = run_py(code)
        assert "CUM" in out


class TestElasticRestore:
    def test_restore_onto_different_mesh(self, tmp_path):
        code = f"""
            import jax, jax.numpy as jnp, numpy as np
            from repro.checkpoint import ckpt
            from repro.distributed.sharding import use_mesh, sharding_for
            tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
            mesh_a = jax.make_mesh((4, 2), ("data", "model"))
            with use_mesh(mesh_a):
                sh_a = {{"w": sharding_for((8, 8), ("embed", "mlp"), mesh_a)}}
                tree_a = jax.device_put(tree, sh_a)
                ckpt.save({str(tmp_path)!r}, 3, tree_a)
            # restore onto a DIFFERENT mesh shape (elastic restart: 8 -> 4 devices)
            mesh_b = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
            with use_mesh(mesh_b):
                sh_b = {{"w": sharding_for((8, 8), ("embed", "mlp"), mesh_b)}}
                restored, step, _ = ckpt.restore({str(tmp_path)!r}, tree, shardings=sh_b)
            assert step == 3
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
            assert restored["w"].sharding.mesh.devices.size == 4
            print("ELASTIC_OK")
        """
        out = run_py(code)
        assert "ELASTIC_OK" in out
