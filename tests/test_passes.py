"""E4: the repro.passes optimization pipeline.

Per-pass golden tests (graph in → graph out), the declarative rewrite engine,
pipeline idempotence, the reference-runtime conformance hook, and end-to-end
bit-exactness of optimized-then-compiled MLP/CNN artifacts.
"""
import json

import numpy as np
import pytest

from repro import passes
from repro.core import patterns, pqir, quant
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import CNNSpec, ConvLayerSpec, MLPSpec, quantize_cnn, quantize_mlp
from repro.passes.canonicalize import AddFold, ConstantFold, DeadCode, IdentityElim, MulFold, QdqCancel
from repro.passes.sink import SinkShapes


def _ops(graph):
    return [n.op_type for n in graph.toposorted()]


def _run_one(pass_obj, model):
    opt = passes.clone_model(model)
    counters = pass_obj.run(opt.graph)
    return opt, counters


def _mlp_model(rng=None, activations=("Relu", "Relu", None)):
    rng = rng or np.random.default_rng(0)
    n = len(activations)
    dims = [64] + [32] * (n - 1) + [10]
    spec = MLPSpec(
        weights=[rng.normal(size=(dims[i], dims[i + 1])).astype(np.float32) * 0.2 for i in range(n)],
        biases=[rng.normal(size=(dims[i + 1],)).astype(np.float32) * 0.1 for i in range(n)],
        activations=list(activations),
    )
    calib = rng.normal(size=(128, 64)).astype(np.float32)
    model = quantize_mlp(spec, calib)
    xq = quant.quantize(
        rng.normal(size=(8, 64)).astype(np.float32), eval(model.metadata["input_scale"]), "int8"
    )
    return model, xq


class TestConstantFold:
    def test_folds_all_initializer_subgraph(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 4))
        a = gb.add_initializer("a", np.ones((4,), np.float32))
        b = gb.add_initializer("b", np.full((4,), 2.0, np.float32))
        s = gb.op("Add", [a, b], out_hint="s")  # const + const → foldable
        y = gb.op("Mul", [x, s], out_hint="y")
        gb.add_output(y, "float32", (None, 4))
        model = gb.build()
        opt, counters = _run_one(ConstantFold(), model)
        assert counters["folded"] == 1
        assert _ops(opt.graph) == ["Mul"]
        assert np.array_equal(opt.graph.initializers[s], np.full((4,), 3.0, np.float32))

    def test_never_folds_graph_outputs(self):
        gb = pqir.GraphBuilder("g")
        gb.add_input("x", "float32", (2,))
        a = gb.add_initializer("a", np.ones((2,), np.float32))
        y = gb.op("Add", [a, a], out_hint="y")
        gb.add_output(y, "float32", (2,))
        model = gb.build()
        _, counters = _run_one(ConstantFold(), model)
        assert counters["folded"] == 0


class TestQdqCancel:
    def _model(self, scale_out=0.5, zp_dtype="int8"):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int8", (None, 8))
        r = gb.op("Relu", [x], out_hint="r")
        s1 = gb.add_initializer("s1", np.float32(0.5))
        z1 = gb.add_initializer("z1", np.zeros((), "int8"))
        d = gb.op("DequantizeLinear", [r, s1, z1], out_hint="d")
        s2 = gb.add_initializer("s2", np.float32(scale_out))
        z2 = gb.add_initializer("z2", np.zeros((), zp_dtype))
        q = gb.op("QuantizeLinear", [d, s2, z2], out_hint="q")
        gb.add_output(q, zp_dtype, (None, 8))
        return gb.build(), q

    def test_cancels_matching_roundtrip(self):
        model, q = self._model()
        opt, counters = _run_one(QdqCancel(), model)
        assert counters["eliminated"] == 2
        assert _ops(opt.graph) == ["Relu"]
        # the public output name survives the rewrite
        assert opt.graph.nodes[0].outputs == [q]
        x = np.random.default_rng(0).integers(-128, 128, (4, 8)).astype(np.int8)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": x})[q], ReferenceRuntime(opt).run({"x": x})[q]
        )

    def test_keeps_mismatched_scale(self):
        model, _ = self._model(scale_out=0.25)
        _, counters = _run_one(QdqCancel(), model)
        assert counters["eliminated"] == 0

    def test_keeps_mismatched_dtype(self):
        model, _ = self._model(zp_dtype="uint8")  # int8 in, uint8 out: lossy
        _, counters = _run_one(QdqCancel(), model)
        assert counters["eliminated"] == 0

    def test_cancels_per_channel_roundtrip(self):
        """Per-channel scale vectors cancel too — the round trip is the
        identity elementwise."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int8", (None, 8))
        r = gb.op("Relu", [x], out_hint="r")
        s = gb.add_initializer("s", np.linspace(0.1, 0.8, 8).astype(np.float32))
        z = gb.add_initializer("z", np.zeros((8,), "int8"))
        d = gb.op("DequantizeLinear", [r, s, z], out_hint="d")
        q = gb.op("QuantizeLinear", [d, s, z], out_hint="q")
        gb.add_output(q, "int8", (None, 8))
        model = gb.build()
        opt, counters = _run_one(QdqCancel(), model)
        assert counters["eliminated"] == 2
        xv = np.random.default_rng(3).integers(-128, 128, (4, 8)).astype(np.int8)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": xv})[q], ReferenceRuntime(opt).run({"x": xv})[q]
        )

    def test_keeps_rank_expanding_scale(self):
        """A (1, 1, N) scale broadcasts the 2-D data up to rank 3, so the
        'round trip' actually reshapes its input — cancelling it would change
        the graph's output shape.  Keep the pair."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int8", (4, 8))
        r = gb.op("Relu", [x], out_hint="r")
        s = gb.add_initializer("s", np.full((1, 1, 8), 0.5, np.float32))
        z = gb.add_initializer("z", np.zeros((1, 1, 8), "int8"))
        d = gb.op("DequantizeLinear", [r, s, z], out_hint="d")
        q = gb.op("QuantizeLinear", [d, s, z], out_hint="q")
        gb.add_output(q, "int8", (1, 4, 8))
        model = gb.build()
        assert ReferenceRuntime(model).run(
            {"x": np.zeros((4, 8), np.int8)}
        )[q].shape == (1, 4, 8)
        _, counters = _run_one(QdqCancel(), model)
        assert counters["eliminated"] == 0

    def test_keeps_per_channel_axis_mismatch(self):
        """Same scale vector but different quantization axes is not a
        round trip — keep the pair."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int8", (None, 8))
        r = gb.op("Relu", [x], out_hint="r")
        s = gb.add_initializer("s", np.linspace(0.1, 0.8, 8).astype(np.float32))
        z = gb.add_initializer("z", np.zeros((8,), "int8"))
        d = gb.op("DequantizeLinear", [r, s, z], out_hint="d", axis=0)
        q = gb.op("QuantizeLinear", [d, s, z], out_hint="q", axis=1)
        gb.add_output(q, "int8", (None, 8))
        _, counters = _run_one(QdqCancel(), gb.build())
        assert counters["eliminated"] == 0

    def test_keeps_wide_integer_dtype(self):
        """int32 round-trips are NOT cancelled: above 2**24 the f32 products
        lose bits, so the chain is not the identity."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int32", (4,))
        r = gb.op("Relu", [x], out_hint="r")
        s = gb.add_initializer("s", np.float32(0.3))
        z = gb.add_initializer("z", np.zeros((), "int32"))
        d = gb.op("DequantizeLinear", [r, s, z], out_hint="d")
        q = gb.op("QuantizeLinear", [d, s, z], out_hint="q")
        gb.add_output(q, "int32", (4,))
        model = gb.build()
        opt, counters = _run_one(QdqCancel(), model)
        assert counters["eliminated"] == 0
        xv = np.asarray([2**24 + 1, 2**30, 5, 2**24 + 3], np.int32)
        # the chain itself is lossy here — cancelling it would change outputs
        assert not np.array_equal(ReferenceRuntime(model).run({"x": xv})[q], xv)


class TestMulFold:
    def _rescale_chain(self, c1, c2):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 8))
        a = gb.add_initializer("qs", np.asarray(c1, np.float32))
        b = gb.add_initializer("sh", np.asarray(c2, np.float32))
        m1 = gb.op("Mul", [x, a], out_hint="m1")
        m2 = gb.op("Mul", [m1, b], out_hint="m2")
        gb.add_output(m2, "float32", (None, 8))
        return gb.build(), m2

    def test_folds_pow2_pair_bitexact(self):
        model, y = self._rescale_chain(361.0, 2.0**-13)
        opt, counters = _run_one(MulFold(), model)
        assert counters == {"folded": 1, "eliminated": 1}
        assert _ops(opt.graph) == ["Mul"]
        x = np.random.default_rng(1).normal(size=(64, 8)).astype(np.float32) * 1e4
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": x})[y], ReferenceRuntime(opt).run({"x": x})[y]
        )

    def test_refuses_non_pow2(self):
        model, _ = self._rescale_chain(0.3, 0.7)  # neither is a power of two
        _, counters = _run_one(MulFold(), model)
        assert counters["folded"] == 0

    def test_refuses_shared_intermediate(self):
        model, _ = self._rescale_chain(361.0, 2.0**-13)
        # make the first Mul's output observable → no longer single-consumer
        m1_out = model.graph.nodes[0].outputs[0]
        model.graph.outputs.append(pqir.TensorInfo(m1_out, "float32", (None, 8)))
        _, counters = _run_one(MulFold(), model)
        assert counters["folded"] == 0

    def test_folds_per_channel_pair_bitexact(self):
        """The §3.1 pair with *vector* constants: per-channel quant_scale ×
        per-channel 2**-N (every shift lane a power of two) folds to one
        vector Mul, bit-exactly."""
        rng = np.random.default_rng(7)
        qs = rng.integers(1, 2**24, 8).astype(np.float32)
        sh = (2.0 ** -rng.integers(10, 30, 8)).astype(np.float32)
        model, y = self._rescale_chain(qs, sh)
        opt, counters = _run_one(MulFold(), model)
        assert counters == {"folded": 1, "eliminated": 1}
        x = rng.normal(size=(64, 8)).astype(np.float32) * 1e4
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": x})[y], ReferenceRuntime(opt).run({"x": x})[y]
        )

    def test_folds_mixed_scalar_vector_broadcast(self):
        """Scalar pow2 shift against a per-channel scale vector (and the
        reverse) — broadcast-compatible pairs fold."""
        qs = np.arange(1, 9, dtype=np.float32).reshape(1, 8)
        model, y = self._rescale_chain(qs, 2.0**-5)
        opt, counters = _run_one(MulFold(), model)
        assert counters["folded"] == 1
        x = np.random.default_rng(8).normal(size=(4, 8)).astype(np.float32)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": x})[y], ReferenceRuntime(opt).run({"x": x})[y]
        )

    def test_refuses_incompatible_shapes(self):
        model, _ = self._rescale_chain(np.full((3,), 2.0, np.float32), np.full((8,), 2.0, np.float32))
        _, counters = _run_one(MulFold(), model)
        assert counters["folded"] == 0

    def test_refuses_orthogonal_outer_product(self):
        """(1, K) × (K, 1) broadcasts, but folding would materialize the
        O(K²) outer product as an initializer — keep the pair."""
        model, _ = self._rescale_chain(
            np.full((1, 8), 2.0, np.float32), np.full((8, 1), 4.0, np.float32)
        )
        _, counters = _run_one(MulFold(), model)
        assert counters["folded"] == 0

    def test_refuses_per_channel_non_pow2_pair(self):
        """Two non-pow2 vectors stay split — per-channel relaxation does not
        weaken the rounding-exactness gate."""
        rng = np.random.default_rng(9)
        model, _ = self._rescale_chain(
            rng.uniform(0.1, 0.9, 8).astype(np.float32), rng.uniform(0.1, 0.9, 8).astype(np.float32)
        )
        _, counters = _run_one(MulFold(), model)
        assert counters["folded"] == 0


class TestAddFold:
    def _bias_chain(self, c1, c2, dtype="int32", xdtype="int32"):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", xdtype, (None, 4))
        a = gb.add_initializer("b1", np.asarray(c1, dtype))
        b = gb.add_initializer("b2", np.asarray(c2, dtype))
        a1 = gb.op("Add", [x, a], out_hint="a1")
        a2 = gb.op("Add", [a1, b], out_hint="a2")
        gb.add_output(a2, xdtype, (None, 4))
        return gb.build(), a2

    def test_folds_integer_bias_pair_bitexact(self):
        model, y = self._bias_chain([1, 2, 3, 4], [10, 20, 30, 40])
        opt, counters = _run_one(AddFold(), model)
        assert counters == {"folded": 1, "eliminated": 1}
        assert _ops(opt.graph) == ["Add"]
        x = np.random.default_rng(0).integers(-(2**20), 2**20, (16, 4)).astype(np.int32)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": x})[y], ReferenceRuntime(opt).run({"x": x})[y]
        )

    def test_wraparound_stays_exact(self):
        """Two's-complement associativity: folding is exact even when the
        intermediate sum overflows int32."""
        big = np.iinfo(np.int32).max - 1
        model, y = self._bias_chain([big] * 4, [big] * 4)
        opt, counters = _run_one(AddFold(), model)
        assert counters["folded"] == 1
        x = np.random.default_rng(1).integers(-100, 100, (8, 4)).astype(np.int32)
        with np.errstate(over="ignore"):
            np.testing.assert_array_equal(
                ReferenceRuntime(model).run({"x": x})[y], ReferenceRuntime(opt).run({"x": x})[y]
            )

    def test_narrow_consts_fold_in_compute_dtype(self):
        """Regression: c1 = c2 = int8 100 feeding an int32 x must fold to
        +200 (the sequential adds compute at int32), not wrap to -56 in
        int8."""
        model, y = self._bias_chain([100] * 4, [100] * 4, dtype="int8", xdtype="int32")
        opt, counters = _run_one(AddFold(), model)
        assert counters["folded"] == 1
        folded_c = next(v for k, v in opt.graph.initializers.items() if "folded_bias" in k)
        assert folded_c.dtype == np.int32 and int(folded_c[0]) == 200
        x = np.random.default_rng(2).integers(-100, 100, (8, 4)).astype(np.int32)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": x})[y], ReferenceRuntime(opt).run({"x": x})[y]
        )

    def test_refuses_widening_second_add(self):
        """(x_int8 + c1_int8) wraps at int8 before the int32 second add sees
        it — folding at int32 would skip that wraparound, so keep the pair."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int8", (None, 4))
        a = gb.add_initializer("b1", np.asarray([100] * 4, np.int8))
        b = gb.add_initializer("b2", np.asarray([100] * 4, np.int32))
        a1 = gb.op("Add", [x, a], out_hint="a1")
        a2 = gb.op("Add", [a1, b], out_hint="a2")
        gb.add_output(a2, "int32", (None, 4))
        model = gb.build()
        _, counters = _run_one(AddFold(), model)
        assert counters["folded"] == 0

    def test_refuses_float_pair(self):
        """Float addition does not associate — the pair must be kept."""
        model, _ = self._bias_chain([0.1] * 4, [0.2] * 4, dtype="float32", xdtype="float32")
        _, counters = _run_one(AddFold(), model)
        assert counters["folded"] == 0

    def test_folds_broadcast_compatible_pair(self):
        """Per-channel bias against a scalar correction (mixed shapes) folds —
        integer addition associates elementwise under any broadcast."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int32", (None, 4))
        a = gb.add_initializer("b1", np.asarray([1, 2, 3, 4], np.int32))
        b = gb.add_initializer("b2", np.asarray(7, np.int32))
        a1 = gb.op("Add", [x, a], out_hint="a1")
        a2 = gb.op("Add", [a1, b], out_hint="a2")
        gb.add_output(a2, "int32", (None, 4))
        model = gb.build()
        opt, counters = _run_one(AddFold(), model)
        assert counters["folded"] == 1
        xv = np.random.default_rng(5).integers(-100, 100, (8, 4)).astype(np.int32)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": xv})[a2], ReferenceRuntime(opt).run({"x": xv})[a2]
        )

    def test_idempotent_in_pipeline(self):
        model, _ = self._bias_chain([1, 2, 3, 4], [10, 20, 30, 40])
        once, rep1 = passes.optimize(model)
        twice, rep2 = passes.optimize(once)
        assert rep1.total("folded") >= 1
        assert not rep2.changed
        assert json.dumps(once.to_json()) == json.dumps(twice.to_json())


class TestIdentityAndDeadCode:
    def test_same_dtype_cast_and_mul_by_one(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 4))
        one = gb.add_initializer("one", np.float32(1.0))
        c = gb.op("Cast", [x], out_hint="c", to="float32")
        m = gb.op("Mul", [c, one], out_hint="m")
        r = gb.op("Relu", [m], out_hint="r")
        gb.add_output(r, "float32", (None, 4))
        model = gb.build()
        opt, counters = _run_one(IdentityElim(), model)
        assert counters["eliminated"] == 2
        assert _ops(opt.graph) == ["Relu"]

    def test_dtype_promoting_mul_by_one_is_kept(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int32", (None, 4))
        one = gb.add_initializer("one", np.float32(1.0))
        m = gb.op("Mul", [x, one], out_hint="m")
        gb.add_output(m, "float32", (None, 4))
        model = gb.build()
        _, counters = _run_one(IdentityElim(), model)
        assert counters["eliminated"] == 0

    def test_rank_expanding_size1_const_kept(self):
        """Add(x(4,), zeros(1,1,1)) broadcasts x up to rank 3 — removing it
        would change the output shape, so it is not an identity."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (4,))
        z = gb.add_initializer("z", np.zeros((1, 1, 1), np.float32))
        a = gb.op("Add", [x, z], out_hint="a")
        r = gb.op("Relu", [a], out_hint="r")
        gb.add_output(r, "float32", (1, 1, 4))
        model = gb.build()
        _, counters = _run_one(IdentityElim(), model)
        assert counters["eliminated"] == 0

    def test_dead_nodes_and_inits_removed(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 4))
        unused = gb.add_initializer("unused", np.float32(7.0))
        gb.op("Mul", [x, unused], out_hint="orphan")  # never consumed
        y = gb.op("Relu", [x], out_hint="y")
        gb.add_output(y, "float32", (None, 4))
        model = gb.build()
        opt, counters = _run_one(DeadCode(), model)
        assert counters["eliminated"] == 1 and counters["pruned_inits"] == 1
        assert _ops(opt.graph) == ["Relu"] and not opt.graph.initializers


class TestSinkShapes:
    def test_transpose_sinks_past_relu(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (2, 3))
        t = gb.op("Transpose", [x], out_hint="t", perm=[1, 0])
        r = gb.op("Relu", [t], out_hint="r")
        gb.add_output(r, "float32", (3, 2))
        model = gb.build()
        opt, counters = _run_one(SinkShapes(), model)
        assert counters["sunk"] == 1
        assert _ops(opt.graph) == ["Relu", "Transpose"]
        xv = np.random.default_rng(0).normal(size=(2, 3)).astype(np.float32)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": xv})[r], ReferenceRuntime(opt).run({"x": xv})[r]
        )

    def test_reshape_sinks_through_scalar_mul_chain(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (4, 6))
        shape = gb.add_initializer("shape", np.asarray([2, 12], np.int64))
        c = gb.add_initializer("c", np.float32(2.0))
        rs = gb.op("Reshape", [x, shape], out_hint="rs")
        m = gb.op("Mul", [rs, c], out_hint="m")
        r = gb.op("Relu", [m], out_hint="r")
        gb.add_output(r, "float32", (2, 12))
        model = gb.build()
        opt, counters = _run_one(SinkShapes(), model)
        assert counters["sunk"] == 2  # sinks past Mul, then past Relu
        assert _ops(opt.graph) == ["Mul", "Relu", "Reshape"]
        xv = np.random.default_rng(1).normal(size=(4, 6)).astype(np.float32)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": xv})[r], ReferenceRuntime(opt).run({"x": xv})[r]
        )

    def test_flatten_sinks_past_relu_golden(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (2, 3, 4))
        f = gb.op("Flatten", [x], out_hint="f", axis=1)
        r = gb.op("Relu", [f], out_hint="r")
        gb.add_output(r, "float32", (2, 12))
        model = gb.build()
        opt, counters = _run_one(SinkShapes(), model)
        assert counters["sunk"] == 1
        assert _ops(opt.graph) == ["Relu", "Flatten"]
        xv = np.random.default_rng(2).normal(size=(2, 3, 4)).astype(np.float32)
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"x": xv})[r], ReferenceRuntime(opt).run({"x": xv})[r]
        )

    def test_flatten_sink_idempotent_in_pipeline(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (2, 3, 4))
        c = gb.add_initializer("c", np.float32(0.5))
        f = gb.op("Flatten", [x], out_hint="f", axis=1)
        m = gb.op("Mul", [f, c], out_hint="m")
        r = gb.op("Relu", [m], out_hint="r")
        gb.add_output(r, "float32", (2, 12))
        model = gb.build()
        once, rep1 = passes.optimize(model)
        twice, rep2 = passes.optimize(once)
        assert rep1.total("sunk") == 2  # Flatten sinks past Mul, then Relu
        assert not rep2.changed
        assert json.dumps(once.to_json()) == json.dumps(twice.to_json())
        assert _ops(once.graph) == ["Mul", "Relu", "Flatten"]

    def test_per_channel_operand_blocks_sinking(self):
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (2, 3))
        c = gb.add_initializer("c", np.arange(6, dtype=np.float32).reshape(3, 2))
        t = gb.op("Transpose", [x], out_hint="t", perm=[1, 0])
        m = gb.op("Mul", [t, c], out_hint="m")
        gb.add_output(m, "float32", (3, 2))
        model = gb.build()
        _, counters = _run_one(SinkShapes(), model)
        assert counters["sunk"] == 0


class TestRewriteEngine:
    def test_match_captures_chain_and_consts(self):
        from repro.core.compile import QLINEAR_PATTERN
        from repro.passes.analysis import GraphAnalysis
        from repro.passes.rewrite import match_chain

        rng = np.random.default_rng(0)
        p = quant.quantize_linear_layer(
            rng.normal(size=(16, 8)).astype(np.float32) * 0.1,
            rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1)
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int8", (None, 16))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
        gb.add_output(y, "int8", (None, 8))
        g = gb.build().graph
        anchor = g.toposorted()[0]
        m = match_chain(GraphAnalysis(g), anchor, QLINEAR_PATTERN)
        assert m is not None
        assert [n.op_type for n in m.nodes] == [
            "MatMulInteger", "Add", "Cast", "Mul", "Mul", "Relu", "QuantizeLinear"]
        assert m.consts["weight"].dtype == np.int8
        assert m.node("relu") is not None and "mul2" in m
        assert m.out_tensor == y

    def test_multi_consumer_intermediate_blocks_match(self):
        from repro.core.compile import QLINEAR_PATTERN
        from repro.passes.analysis import GraphAnalysis
        from repro.passes.rewrite import match_chain

        rng = np.random.default_rng(0)
        p = quant.quantize_linear_layer(
            rng.normal(size=(16, 8)).astype(np.float32) * 0.1, None, 0.05, 0.1)
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "int8", (None, 16))
        y = patterns.fc_layer(gb, x, p, "fc0", two_mul=False)
        gb.add_output(y, "int8", (None, 8))
        model = gb.build()
        # expose the accumulator as a second output → anchor's edge fans out
        acc = model.graph.nodes[0].outputs[0]
        model.graph.outputs.append(pqir.TensorInfo(acc, "int32", (None, 8)))
        g = model.graph
        m = match_chain(GraphAnalysis(g), g.toposorted()[0], QLINEAR_PATTERN)
        assert m is None


class TestPassManager:
    def test_toggle_disables_pass(self):
        model, _ = _mlp_model()
        _, rep_all = passes.optimize(model)
        _, rep_nofold = passes.optimize(model, disable=("mul_fold",))
        assert rep_all.total("folded") == 3
        assert rep_nofold.total("folded") == 0

    def test_pipeline_idempotent(self):
        model, _ = _mlp_model()
        opt1, rep1 = passes.optimize(model)
        opt2, rep2 = passes.optimize(opt1)
        assert rep1.changed and not rep2.changed
        assert json.dumps(opt1.to_json()) == json.dumps(opt2.to_json())

    def test_conformance_hook_accepts_good_passes(self):
        model, _ = _mlp_model()
        _, rep = passes.optimize(model, verify=True)
        assert rep.total("eliminated") >= 1  # and no ConformanceError raised

    def test_conformance_hook_catches_bad_pass(self):
        class EvilPass(passes.Pass):
            name = "evil"

            def run(self, graph):
                for node in graph.nodes:
                    if node.op_type == "Relu":
                        node.op_type = "Sigmoid"  # obviously not semantics-preserving
                        return {"eliminated": 1}
                return {}

        model, _ = _mlp_model()
        pm = passes.PassManager([EvilPass()], verify=True)
        with pytest.raises(passes.ConformanceError, match="evil"):
            pm.run(model)

    def test_original_model_never_mutated(self):
        model, _ = _mlp_model()
        before = json.dumps(model.to_json())
        passes.optimize(model)
        assert json.dumps(model.to_json()) == before


class TestOptimizedCompileEndToEnd:
    def test_mlp_bitexact_and_nodes_eliminated(self):
        model, xq = _mlp_model()
        ref = ReferenceRuntime(model).run({"input_q": xq})
        cm = compile_model(model, verify_passes=True)
        assert cm.stats["fused_qlinear"] == 3 and cm.stats["generic"] == 0
        assert cm.stats["eliminated"] >= 1  # two-Mul rescales folded away
        got = cm.run({"input_q": xq})
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])

    def test_tanh_mlp_lut_still_fuses_after_passes(self):
        model, xq = _mlp_model(activations=("Relu", "Tanh", None))
        ref = ReferenceRuntime(model).run({"input_q": xq})
        cm = compile_model(model, verify_passes=True)
        assert cm.stats["fused_lut"] == 1 and cm.stats["generic"] == 0
        got = cm.run({"input_q": xq})
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])

    def test_cnn_bitexact(self):
        rng = np.random.default_rng(5)
        spec = CNNSpec(
            convs=[ConvLayerSpec(rng.normal(size=(4, 1, 3, 3)).astype(np.float32) * 0.3,
                                 rng.normal(size=(4,)).astype(np.float32) * 0.1,
                                 activation="Relu")],
            head=MLPSpec(weights=[rng.normal(size=(4 * 6 * 6, 10)).astype(np.float32) * 0.1],
                         biases=[rng.normal(size=(10,)).astype(np.float32) * 0.1],
                         activations=[None]),
        )
        calib = rng.normal(size=(64, 1, 8, 8)).astype(np.float32)
        model = quantize_cnn(spec, calib)
        xq = quant.quantize(calib[:4], eval(model.metadata["input_scale"]), "int8")
        ref = ReferenceRuntime(model).run({"input_q": xq})
        cm = compile_model(model, verify_passes=True)
        assert cm.stats["fused_qconv"] == 1 and cm.stats["fused_qlinear"] == 1
        got = cm.run({"input_q": xq})
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k])

    def test_optimize_off_matches_optimize_on(self):
        model, xq = _mlp_model()
        on = compile_model(model).run({"input_q": xq})
        off = compile_model(model, optimize=False).run({"input_q": xq})
        for k in on:
            np.testing.assert_array_equal(on[k], off[k])
