"""E3: compiled TPU backend ≡ reference runtime — bit-exact on integer paths.

This is the conformance test that makes the co-design separation real: the
quantizer's artifact runs identically on the "standard tool" (reference
runtime) and on the hardware-specific compiled backend.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import patterns, pqir, quant
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import CNNSpec, ConvLayerSpec, MLPSpec, quantize_cnn, quantize_mlp


def _fc_model(rng, two_mul=True, activation=None, n_in=64, n_out=32):
    x = rng.normal(size=(8, n_in)).astype(np.float32)
    w = rng.normal(size=(n_in, n_out)).astype(np.float32) * 0.1
    b = rng.normal(size=(n_out,)).astype(np.float32) * 0.2
    scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
    p = quant.quantize_linear_layer(w, b, scale_x, 0.1)
    xq = quant.quantize(x, scale_x, "int8")
    gb = pqir.GraphBuilder("m")
    xi = gb.add_input("input_q", "int8", (None, n_in))
    y = patterns.fc_layer(gb, xi, p, "fc0", two_mul=two_mul, activation=activation)
    gb.add_output(y, "int8", (None, n_out))
    return gb.build(), xq, y


class TestFusionBitExact:
    @pytest.mark.parametrize("two_mul", [True, False])
    @pytest.mark.parametrize("activation", [None, "Relu"])
    def test_fig12_fused_equals_runtime(self, two_mul, activation):
        rng = np.random.default_rng(0)
        model, xq, yname = _fc_model(rng, two_mul, activation)
        ref_out = ReferenceRuntime(model).run({"input_q": xq})[yname]
        for backend in ("ref", "interpret"):
            cm = compile_model(model, backend=backend)
            assert cm.stats["fused_qlinear"] == 1, cm.stats
            assert cm.stats["generic"] == 0  # the whole chain fused
            got = cm.run({"input_q": xq})[yname]
            np.testing.assert_array_equal(got, ref_out)

    @pytest.mark.parametrize("fn,name", [
        (patterns.fc_int8_tanh, "int8_tanh"),
        (patterns.fc_fp16_tanh, "fp16_tanh"),
        (patterns.fc_fp16_sigmoid, "fp16_sigmoid"),
    ])
    def test_fig456_lut_fused_bitexact(self, fn, name):
        """The compiled LUT reproduces the DQL→[f16]→act→QL chain bit-exactly —
        including the fp16 rounding of Figs 5/6."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(16, 32)).astype(np.float32)
        w = rng.normal(size=(32, 16)).astype(np.float32) * 0.3
        b = rng.normal(size=(16,)).astype(np.float32) * 0.1
        scale_x = quant.choose_scale(float(np.abs(x).max()), "int8")
        absmax = patterns.SIGMOID_INPUT_ABSMAX if "sigmoid" in name else patterns.TANH_INPUT_ABSMAX
        p = quant.quantize_linear_layer(w, b, scale_x, absmax / 127.0)
        xq = quant.quantize(x, scale_x, "int8")
        gb = pqir.GraphBuilder("m")
        xi = gb.add_input("input_q", "int8", (None, 32))
        y = fn(gb, xi, p, "fc0")
        out_dtype = "uint8" if "sigmoid" in name else "int8"
        gb.add_output(y, out_dtype, (None, 16))
        model = gb.build()
        ref_out = ReferenceRuntime(model).run({"input_q": xq})[y]
        cm = compile_model(model, backend="ref")
        assert cm.stats["fused_lut"] == 1, cm.stats
        assert cm.stats["fused_qlinear"] == 1
        got = cm.run({"input_q": xq})[y]
        np.testing.assert_array_equal(got, ref_out)

    def test_conv_chain_fused(self):
        rng = np.random.default_rng(2)
        w = rng.integers(-128, 128, (8, 3, 3, 3)).astype(np.int8)
        b = rng.integers(-100, 100, (8,)).astype(np.int32)
        r = quant.decompose_multiplier(0.002)
        gb = pqir.GraphBuilder("c")
        xi = gb.add_input("x", "int8", (None, 3, 10, 10))
        y = patterns.conv_layer(gb, xi, w, b, r, "c0", pads=(1, 1, 1, 1), activation="Relu")
        gb.add_output(y, "int8", (None, 8, 10, 10))
        model = gb.build()
        x = rng.integers(-128, 128, (2, 3, 10, 10)).astype(np.int8)
        ref_out = ReferenceRuntime(model).run({"x": x})[y]
        cm = compile_model(model)
        assert cm.stats["fused_qconv"] == 1
        np.testing.assert_array_equal(cm.run({"x": x})[y], ref_out)

    def test_per_row_mul_constant_falls_back_unfused(self):
        """A Mul constant broadcasting along the *batch* axis is not a
        per-channel rescale — the chain must not fuse (the fused kernel only
        knows output-feature vectors) but must still compile correctly via
        the generic mirror."""
        rng = np.random.default_rng(9)
        gb = pqir.GraphBuilder("m")
        xi = gb.add_input("x", "int8", (4, 16))
        w = gb.add_initializer("w", rng.integers(-128, 128, (16, 8)).astype(np.int8))
        acc = gb.op("MatMulInteger", [xi, w], out_hint="acc")
        f = gb.op("Cast", [acc], out_hint="f", to="float32")
        per_row = gb.add_initializer("per_row", np.full((4, 1), 2.0**-9, np.float32))
        m = gb.op("Mul", [f, per_row], out_hint="m")
        y = patterns.emit_round_clip(gb, m, "out")
        gb.add_output(y, "int8", (4, 8))
        model = gb.build()
        xq = rng.integers(-128, 128, (4, 16)).astype(np.int8)
        ref_out = ReferenceRuntime(model).run({"x": xq})[y]
        cm = compile_model(model)
        assert cm.stats["fused_qlinear"] == 0 and cm.stats["generic"] > 0, cm.stats
        np.testing.assert_array_equal(cm.run({"x": xq})[y], ref_out)

    def test_rank_expanding_mul_constant_falls_back(self):
        """A (1, 1, N) rescale constant broadcasts the 2-D accumulator up to
        rank 3 in the reference runtime — fusing it would silently drop that
        dim, so the chain must compile via the generic mirror instead."""
        rng = np.random.default_rng(11)
        gb = pqir.GraphBuilder("m")
        xi = gb.add_input("x", "int8", (4, 16))
        w = gb.add_initializer("w", rng.integers(-128, 128, (16, 8)).astype(np.int8))
        acc = gb.op("MatMulInteger", [xi, w], out_hint="acc")
        f = gb.op("Cast", [acc], out_hint="f", to="float32")
        c = gb.add_initializer("c", np.full((1, 1, 8), 2.0**-9, np.float32))
        m = gb.op("Mul", [f, c], out_hint="m")
        y = patterns.emit_round_clip(gb, m, "out")
        gb.add_output(y, "int8", (1, 4, 8))
        model = gb.build()
        xq = rng.integers(-128, 128, (4, 16)).astype(np.int8)
        ref_out = ReferenceRuntime(model).run({"x": xq})[y]
        assert ref_out.shape == (1, 4, 8)
        cm = compile_model(model, optimize=False)
        assert cm.stats["fused_qlinear"] == 0 and cm.stats["generic"] > 0, cm.stats
        got = cm.run({"x": xq})[y]
        np.testing.assert_array_equal(got, ref_out)

    def test_gemm_codified_fc_fuses(self):
        """ROADMAP follow-up #2: a Gemm-based MLP export hits the fused
        qlinear path (transB and the C bias fold at plan time)."""
        rng = np.random.default_rng(10)
        w = rng.normal(size=(48, 24)).astype(np.float32) * 0.1
        b = rng.normal(size=(24,)).astype(np.float32) * 0.2
        for per_channel in (False, True):
            p = quant.quantize_linear_layer(w, b, 0.05, 0.1, per_channel=per_channel)
            for trans_b in (False, True):
                gb = pqir.GraphBuilder("g")
                xi = gb.add_input("input_q", "int8", (None, 48))
                y = patterns.fc_layer_gemm(gb, xi, p, "fc0", activation="Relu", trans_b=trans_b)
                gb.add_output(y, "int8", (None, 24))
                model = gb.build()
                xq = rng.integers(-128, 128, (8, 48)).astype(np.int8)
                ref_out = ReferenceRuntime(model).run({"input_q": xq})[y]
                for backend in ("ref", "interpret"):
                    cm = compile_model(model, backend=backend)
                    assert cm.stats["fused_qlinear"] == 1 and cm.stats["generic"] == 0, cm.stats
                    np.testing.assert_array_equal(cm.run({"input_q": xq})[y], ref_out)

    def test_unfused_fallback_still_exact(self):
        """fuse=False exercises the generic jnp mirror — still bit-exact on
        this all-integer graph."""
        rng = np.random.default_rng(3)
        model, xq, yname = _fc_model(rng)
        ref_out = ReferenceRuntime(model).run({"input_q": xq})[yname]
        cm = compile_model(model, fuse=False)
        assert cm.stats["fused_qlinear"] == 0 and cm.stats["generic"] > 0
        np.testing.assert_array_equal(cm.run({"input_q": xq})[yname], ref_out)


class TestEndToEndArtifacts:
    def test_mlp_artifact_compiles_and_matches(self):
        rng = np.random.default_rng(4)
        spec = MLPSpec(
            weights=[rng.normal(size=(32, 64)).astype(np.float32) * 0.2,
                     rng.normal(size=(64, 64)).astype(np.float32) * 0.2,
                     rng.normal(size=(64, 10)).astype(np.float32) * 0.2],
            biases=[rng.normal(size=(64,)).astype(np.float32) * 0.1,
                    rng.normal(size=(64,)).astype(np.float32) * 0.1,
                    rng.normal(size=(10,)).astype(np.float32) * 0.1],
            activations=["Relu", "Tanh", None],
        )
        calib = rng.normal(size=(128, 32)).astype(np.float32)
        model = quantize_mlp(spec, calib)
        xq = quant.quantize(rng.normal(size=(8, 32)).astype(np.float32), eval(model.metadata["input_scale"]), "int8")
        ref_out = ReferenceRuntime(model).run({"input_q": xq})
        cm = compile_model(model)
        assert cm.stats["fused_qlinear"] == 3
        assert cm.stats["fused_lut"] == 1  # the tanh
        got = cm.run({"input_q": xq})
        for k in ref_out:
            np.testing.assert_array_equal(got[k], ref_out[k])

    def test_cnn_artifact_compiles_and_matches(self):
        rng = np.random.default_rng(5)
        spec = CNNSpec(
            convs=[ConvLayerSpec(rng.normal(size=(4, 1, 3, 3)).astype(np.float32) * 0.3,
                                 rng.normal(size=(4,)).astype(np.float32) * 0.1,
                                 activation="Relu")],
            head=MLPSpec(weights=[rng.normal(size=(4 * 6 * 6, 10)).astype(np.float32) * 0.1],
                         biases=[rng.normal(size=(10,)).astype(np.float32) * 0.1],
                         activations=[None]),
        )
        calib = rng.normal(size=(64, 1, 8, 8)).astype(np.float32)
        model = quantize_cnn(spec, calib)
        xq = quant.quantize(calib[:4], eval(model.metadata["input_scale"]), "int8")
        ref_out = ReferenceRuntime(model).run({"input_q": xq})
        cm = compile_model(model)
        assert cm.stats["fused_qconv"] == 1 and cm.stats["fused_qlinear"] == 1
        got = cm.run({"input_q": xq})
        for k in ref_out:
            np.testing.assert_array_equal(got[k], ref_out[k])

    def test_pallas_interpret_end_to_end(self):
        rng = np.random.default_rng(6)
        model, xq, yname = _fc_model(rng, n_in=256, n_out=128)
        ref_out = ReferenceRuntime(model).run({"input_q": xq})[yname]
        cm = compile_model(model, backend="interpret")
        np.testing.assert_array_equal(cm.run({"input_q": xq})[yname], ref_out)


class TestLutKernel:
    def test_lut_kernel_paths(self):
        from repro.kernels import ops as kops
        from repro.kernels.qact_lut import build_lut

        lut = build_lut(np.tanh, 4.0 / 127.0, 1.0 / 127.0, "int8")
        assert lut.shape == (256,) and lut.dtype == np.int8
        x = np.random.default_rng(0).integers(-128, 128, (64, 128)).astype(np.int8)
        expect = lut[x.astype(np.int32) + 128]
        for backend in ("ref", "interpret"):
            got = kops.quantized_activation(jnp.asarray(x), lut, backend=backend)
            np.testing.assert_array_equal(np.asarray(got), expect)
        got = kops.quantized_activation(jnp.asarray(x), lut, backend="interpret", one_hot=True)
        np.testing.assert_array_equal(np.asarray(got), expect)
