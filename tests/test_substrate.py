"""Substrate tests: optimizer, schedules, data pipeline, checkpoint/restart,
fault tolerance, serving engine, end-to-end training loss decrease."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed.fault_tolerance import (
    CheckpointManager,
    CheckpointManagerConfig,
    StragglerMonitor,
    run_resilient,
)
from repro.launch import steps as steps_lib
from repro.models import model as M
from repro.optim import adamw, schedule

# heavyweight model/serving tier — excluded from the fast CI tier (scripts/check.sh)
pytestmark = pytest.mark.slow


class TestOptim:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        opt = adamw.init(params)
        cfg = adamw.AdamWConfig(weight_decay=0.0, grad_clip_norm=None)
        for _ in range(300):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, opt, _ = adamw.update(g, opt, params, jnp.asarray(0.05), cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clip(self):
        params = {"w": jnp.zeros(3)}
        opt = adamw.init(params)
        g = {"w": jnp.full((3,), 1e6)}
        _, _, m = adamw.update(g, opt, params, jnp.asarray(1e-3), adamw.AdamWConfig(grad_clip_norm=1.0))
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_schedules(self):
        wc = schedule.warmup_cosine(jnp.arange(0, 1000, 100), peak_lr=1.0, warmup_steps=100, total_steps=1000)
        assert float(wc[0]) == 0.0 and float(wc[1]) == 1.0
        assert float(wc[-1]) < 0.5
        w = schedule.wsd(jnp.asarray([0, 50, 100, 500, 900, 999]), peak_lr=1.0, warmup_steps=100, stable_steps=700, decay_steps=200)
        np.testing.assert_allclose(np.asarray(w[2:4]), [1.0, 1.0])  # stable phase
        assert float(w[-1]) < 0.2  # decay phase

    def test_wsd_stable_phase_flat_then_decays(self):
        vals = schedule.wsd(jnp.arange(100, 800, 50), peak_lr=2e-4, warmup_steps=100, stable_steps=600, decay_steps=100)
        assert np.allclose(np.asarray(vals[:-1]), 2e-4)


class TestData:
    def test_deterministic_and_sharded(self):
        cfg = get_config("qwen3_1_7b", reduced=True)
        p1 = Pipeline(cfg, DataConfig(seed=7, shard_index=0, shard_count=2))
        p2 = Pipeline(cfg, DataConfig(seed=7, shard_index=1, shard_count=2))
        a = p1.batch(3, 4, 16)
        b = p1.batch(3, 4, 16)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])  # pure fn of step
        assert not np.array_equal(a["tokens"], p2.batch(3, 4, 16)["tokens"])  # shards differ
        assert not np.array_equal(a["tokens"], p1.batch(4, 4, 16)["tokens"])  # steps differ

    def test_family_specific_fields(self):
        enc = Pipeline(get_config("seamless_m4t_large_v2", reduced=True)).batch(0, 2, 16)
        assert "src_embeds" in enc and enc["src_embeds"].shape == (2, 16, 256)
        vlm_cfg = get_config("pixtral_12b", reduced=True)
        vlm = Pipeline(vlm_cfg).batch(0, 2, 16)
        assert vlm["patch_embeds"].shape == (2, vlm_cfg.frontend_tokens, vlm_cfg.d_model)
        assert vlm["tokens"].shape == (2, 16 - vlm_cfg.frontend_tokens)

    def test_file_source(self, tmp_path):
        path = str(tmp_path / "toks.bin")
        np.arange(10_000, dtype=np.uint32).tofile(path)
        cfg = get_config("qwen3_1_7b", reduced=True)
        p = Pipeline(cfg, DataConfig(seed=0, path=path))
        b = p.batch(0, 2, 32)
        assert b["tokens"].shape == (2, 32)
        assert (b["tokens"] >= 0).all() and (b["tokens"] < cfg.vocab_size).all()


class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(6.0).reshape(2, 3) + k, "b": {"c": jnp.ones((4,), jnp.int32) * k}}

    def test_roundtrip(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 5, self._tree(2), extra={"note": "x"})
        restored, step, extra = ckpt.restore(d, self._tree(0))
        assert step == 5 and extra == {"note": "x"}
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(self._tree(2)["a"]))

    def test_latest_pointer_and_gc(self, tmp_path):
        d = str(tmp_path)
        mgr = CheckpointManager(CheckpointManagerConfig(d, interval_steps=1, keep_last=2))
        for s in range(1, 5):
            mgr.maybe_save(s, self._tree(s))
        assert ckpt.latest_step(d) == 4
        assert sorted(p for p in os.listdir(d) if p.startswith("step_")) == ["step_3", "step_4"]

    def test_atomic_no_partial_on_failure(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, self._tree(1))

        class Boom:
            def __array__(self):
                raise RuntimeError("disk died")

        with pytest.raises(RuntimeError):
            ckpt.save(d, 2, {"a": Boom()})
        assert ckpt.latest_step(d) == 1  # old checkpoint intact
        restored, step, _ = ckpt.restore(d, self._tree(0))
        assert step == 1

    def test_resilient_restart_loop(self, tmp_path):
        d = str(tmp_path)
        mgr = CheckpointManager(CheckpointManagerConfig(d, interval_steps=1))
        crashes = {"n": 0}

        def make_state():
            return {"x": jnp.zeros(())}

        def step_fn(state, step):
            if step == 3 and crashes["n"] == 0:
                crashes["n"] += 1
                raise RuntimeError("node failure")
            return {"x": state["x"] + 1}

        final = run_resilient(make_state, step_fn, manager=mgr, total_steps=6)
        assert crashes["n"] == 1
        assert float(final["x"]) == 6.0  # all 6 steps applied exactly once

    def test_straggler_monitor(self):
        import time

        mon = StragglerMonitor(threshold=5.0)
        for s in range(3):
            mon.start_step()
            time.sleep(0.01)
            mon.end_step(s)
        mon.start_step()
        time.sleep(0.2)
        m = mon.end_step(3)
        assert m["straggler"] == 1.0 and mon.slow_steps == [3]


class TestTrainLoop:
    def test_loss_decreases_and_resumes(self, tmp_path):
        from repro.launch.train import train

        d = str(tmp_path / "ck")
        params, opt, hist = train(
            "qwen3_1_7b", steps=8, batch=4, seq=32, ckpt_dir=d, ckpt_interval=4, log_every=100
        )
        assert hist[-1] < hist[0], hist
        # resume from checkpoint: continues at step 5 without blowing up
        params2, opt2, hist2 = train(
            "qwen3_1_7b", steps=10, batch=4, seq=32, ckpt_dir=d, ckpt_interval=100, log_every=100
        )
        assert len(hist2) == 5  # steps 5..9 only
        assert int(opt2["step"]) == 10

    def test_qat_trains(self):
        from repro.launch.train import train

        _, _, hist = train("minicpm_2b", steps=6, batch=4, seq=32, qat=True, log_every=100)
        assert np.isfinite(hist).all() and hist[-1] < hist[0]


class TestServeEngine:
    def test_continuous_batching_drains(self):
        from repro.launch.serve import serve_demo

        reqs, eng = serve_demo("qwen3_1_7b", requests=5, prompt_len=12, new_tokens=4, slots=2)
        assert all(r.done for r in reqs)
        assert all(len(r.generated) == 4 for r in reqs)
        assert eng.metrics["completed"] == 5

    def test_int8_kv_serving_matches_bf16_greedy_mostly(self):
        from repro.launch.serve import serve_demo

        r16, _ = serve_demo("minicpm_2b", requests=3, prompt_len=10, new_tokens=4, slots=3, seed=1)
        r8, _ = serve_demo("minicpm_2b", requests=3, prompt_len=10, new_tokens=4, slots=3, int8_kv=True, seed=1)
        # same prompts, greedy decode: int8 cache should agree on most tokens
        agree = sum(int(a.generated[0] == b.generated[0]) for a, b in zip(r16, r8))
        assert agree >= 2, [r.generated for r in r16 + r8]
