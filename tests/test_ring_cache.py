"""SWA ring-buffer KV cache: decode past the window must equal a full-length
cache with the same sliding-window mask (the §Perf long_500k optimization)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

# heavyweight model/serving tier — excluded from the fast CI tier (scripts/check.sh)
pytestmark = pytest.mark.slow


def test_ring_equals_full_cache_beyond_window():
    cfg = get_config("mixtral_8x22b", reduced=True)  # swa, reduced window=64
    cfg = dataclasses.replace(cfg, window=8)  # tiny window so we wrap quickly
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 6  # prefill shorter than the window
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

    # ring cache (init_cache caps at window=8) vs full cache (force swa off
    # for sizing, keep the window mask via cfg.window during attention)
    ring_cache = M.init_cache(cfg, B, 32)
    assert ring_cache["layers"]["k"].shape[2] == 8  # capped
    full_cfg = dataclasses.replace(cfg, attn_type="full")
    full_cache = M.init_cache(full_cfg, B, 32)
    assert full_cache["layers"]["k"].shape[2] == 32

    swa_masked = cfg  # swa masking, ring storage
    swa_full_store = dataclasses.replace(cfg, window=cfg.window)  # mask only

    lr, ring_cache = M.prefill(params, {"tokens": jnp.asarray(toks)}, swa_masked, ring_cache, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
    # full-store variant: same swa mask but uncapped cache
    class _cfgfull:  # full storage with swa masking: hack via window-masked full cache
        pass

    lf, full_cache = M.prefill(params, {"tokens": jnp.asarray(toks)}, cfg, full_cache, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lf), rtol=1e-5, atol=1e-5)

    # decode 12 tokens — wraps the 8-slot ring
    cur_r, cur_f = ring_cache, full_cache
    tok = jnp.argmax(lr, -1)[:, None].astype(jnp.int32)
    tok_f = tok
    for step in range(12):
        pos = jnp.full((B,), S + step, jnp.int32)
        lr1, cur_r = M.decode_step(params, tok, pos, cur_r, cfg, compute_dtype=jnp.float32)
        lf1, cur_f = M.decode_step(params, tok_f, pos, cur_f, cfg, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lr1), np.asarray(lf1), rtol=2e-4, atol=2e-4)
        tok = jnp.argmax(lr1, -1)[:, None].astype(jnp.int32)
        tok_f = jnp.argmax(lf1, -1)[:, None].astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(tok_f))


def test_prefill_longer_than_window_then_decode():
    """Prompt (24) > window (8): ring keeps the tail; decode logits match a
    full-cache run with the same SWA mask."""
    cfg = get_config("mixtral_8x22b", reduced=True)
    cfg = dataclasses.replace(cfg, window=8)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(3)
    B, S = 2, 24
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

    ring = M.init_cache(cfg, B, S + 8)          # capped at 8
    assert ring["layers"]["k"].shape[2] == 8
    full_cfg = dataclasses.replace(cfg, attn_type="full")
    full = M.init_cache(full_cfg, B, S + 8)     # uncapped storage, swa mask at use

    lr, ring = M.prefill(params, {"tokens": jnp.asarray(toks)}, cfg, ring, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
    lf, full = M.prefill(params, {"tokens": jnp.asarray(toks)}, cfg, full, compute_dtype=jnp.float32, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lf), rtol=1e-5, atol=1e-5)

    tok = jnp.argmax(lr, -1)[:, None].astype(jnp.int32)
    for step in range(6):
        pos = jnp.full((B,), S + step, jnp.int32)
        lr1, ring = M.decode_step(params, tok, pos, ring, cfg, compute_dtype=jnp.float32)
        lf1, full = M.decode_step(params, tok, pos, full, cfg, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lr1), np.asarray(lf1), rtol=2e-4, atol=2e-4)
        tok = jnp.argmax(lr1, -1)[:, None].astype(jnp.int32)
