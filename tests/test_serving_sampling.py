"""E7: serving-engine next-token selection — greedy vs temperature/top-k.

The engine's non-greedy branch used to be dead code (both arms called
argmax); these tests pin the real sampling path.
"""
import numpy as np

from repro.serving.engine import EngineConfig, ServeEngine, sample_token


def _logits(rng, vocab=32):
    return rng.normal(size=(vocab,)).astype(np.float32) * 3.0


class TestSampleToken:
    def test_zero_temperature_is_argmax(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            z = _logits(rng)
            assert sample_token(z, temperature=0.0) == int(z.argmax())

    def test_top_k_one_is_argmax(self):
        rng = np.random.default_rng(1)
        srng = np.random.default_rng(2)
        for _ in range(10):
            z = _logits(rng)
            assert sample_token(z, temperature=1.0, top_k=1, rng=srng) == int(z.argmax())

    def test_top_k_restricts_support(self):
        rng = np.random.default_rng(3)
        z = _logits(rng)
        k = 5
        allowed = set(np.argsort(z)[-k:].tolist())
        srng = np.random.default_rng(4)
        drawn = {sample_token(z, temperature=2.0, top_k=k, rng=srng) for _ in range(300)}
        assert drawn <= allowed
        assert len(drawn) > 1  # it actually samples, not argmax

    def test_low_temperature_concentrates(self):
        rng = np.random.default_rng(5)
        z = _logits(rng)
        srng = np.random.default_rng(6)
        hot = [sample_token(z, temperature=0.01, rng=srng) for _ in range(100)]
        assert np.mean(np.asarray(hot) == z.argmax()) > 0.95

    def test_seeded_rng_is_deterministic(self):
        rng = np.random.default_rng(7)
        z = _logits(rng)
        a = [sample_token(z, temperature=1.0, top_k=4, rng=np.random.default_rng(42)) for _ in range(20)]
        b = [sample_token(z, temperature=1.0, top_k=4, rng=np.random.default_rng(42)) for _ in range(20)]
        assert a == b


class TestEngineSelect:
    def _engine(self, **cfg_kwargs):
        # _select only touches ecfg + _rng; skip the heavy model setup
        eng = object.__new__(ServeEngine)
        eng.ecfg = EngineConfig(**cfg_kwargs)
        eng._rng = np.random.default_rng(eng.ecfg.seed)
        return eng

    def test_greedy_branch(self):
        eng = self._engine(greedy=True)
        z = _logits(np.random.default_rng(8))
        assert eng._select(z) == int(z.argmax())

    def test_sampling_branch_is_not_dead(self):
        """Non-greedy must actually sample — over many draws from a flat-ish
        distribution it cannot always return argmax."""
        eng = self._engine(greedy=False, temperature=5.0, seed=0)
        z = _logits(np.random.default_rng(9))
        draws = {eng._select(z) for _ in range(200)}
        assert len(draws) > 1

    def test_sampling_respects_top_k(self):
        eng = self._engine(greedy=False, temperature=2.0, top_k=3, seed=1)
        z = _logits(np.random.default_rng(10))
        allowed = set(np.argsort(z)[-3:].tolist())
        assert {eng._select(z) for _ in range(200)} <= allowed
