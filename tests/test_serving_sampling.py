"""E7: serving-engine next-token selection — greedy vs temperature/top-k —
plus sampling determinism (the module fallback rng) and the bounded LRU
prefill-function cache.
"""
import numpy as np

from repro.core.cache import LruCache
from repro.serving.engine import (
    EngineConfig,
    OpaqueModelAdapter,
    ServeEngine,
    sample_token,
    seed_sampler,
)


def _logits(rng, vocab=32):
    return rng.normal(size=(vocab,)).astype(np.float32) * 3.0


class TestSampleToken:
    def test_zero_temperature_is_argmax(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            z = _logits(rng)
            assert sample_token(z, temperature=0.0) == int(z.argmax())

    def test_top_k_one_is_argmax(self):
        rng = np.random.default_rng(1)
        srng = np.random.default_rng(2)
        for _ in range(10):
            z = _logits(rng)
            assert sample_token(z, temperature=1.0, top_k=1, rng=srng) == int(z.argmax())

    def test_top_k_restricts_support(self):
        rng = np.random.default_rng(3)
        z = _logits(rng)
        k = 5
        allowed = set(np.argsort(z)[-k:].tolist())
        srng = np.random.default_rng(4)
        drawn = {sample_token(z, temperature=2.0, top_k=k, rng=srng) for _ in range(300)}
        assert drawn <= allowed
        assert len(drawn) > 1  # it actually samples, not argmax

    def test_low_temperature_concentrates(self):
        rng = np.random.default_rng(5)
        z = _logits(rng)
        srng = np.random.default_rng(6)
        hot = [sample_token(z, temperature=0.01, rng=srng) for _ in range(100)]
        assert np.mean(np.asarray(hot) == z.argmax()) > 0.95

    def test_seeded_rng_is_deterministic(self):
        rng = np.random.default_rng(7)
        z = _logits(rng)
        a = [sample_token(z, temperature=1.0, top_k=4, rng=np.random.default_rng(42)) for _ in range(20)]
        b = [sample_token(z, temperature=1.0, top_k=4, rng=np.random.default_rng(42)) for _ in range(20)]
        assert a == b

    def test_no_rng_uses_seeded_module_stream(self):
        """Without an explicit rng the draws come from one seeded module
        stream (not a fresh default_rng per call) — re-seeding replays the
        exact sequence."""
        z = _logits(np.random.default_rng(11))
        seed_sampler(123)
        a = [sample_token(z, temperature=1.5) for _ in range(20)]
        seed_sampler(123)
        b = [sample_token(z, temperature=1.5) for _ in range(20)]
        assert a == b
        # and it is a stream, not a constant: consecutive draws differ somewhere
        assert len(set(a)) > 1

    def test_module_stream_matches_equivalent_generator(self):
        """The fallback draws exactly as an explicitly-threaded generator
        with the same seed would — no hidden extra state."""
        z = _logits(np.random.default_rng(12))
        seed_sampler(7)
        a = [sample_token(z, temperature=1.0, top_k=6) for _ in range(10)]
        rng = np.random.default_rng(7)
        b = [sample_token(z, temperature=1.0, top_k=6, rng=rng) for _ in range(10)]
        assert a == b


class TestEngineSelect:
    def _engine(self, **cfg_kwargs):
        # _select only touches ecfg + _rng; skip the heavy model setup
        eng = object.__new__(ServeEngine)
        eng.ecfg = EngineConfig(**cfg_kwargs)
        eng._rng = np.random.default_rng(eng.ecfg.seed)
        return eng

    def test_greedy_branch(self):
        eng = self._engine(greedy=True)
        z = _logits(np.random.default_rng(8))
        assert eng._select(z) == int(z.argmax())

    def test_sampling_branch_is_not_dead(self):
        """Non-greedy must actually sample — over many draws from a flat-ish
        distribution it cannot always return argmax."""
        eng = self._engine(greedy=False, temperature=5.0, seed=0)
        z = _logits(np.random.default_rng(9))
        draws = {eng._select(z) for _ in range(200)}
        assert len(draws) > 1

    def test_sampling_respects_top_k(self):
        eng = self._engine(greedy=False, temperature=2.0, top_k=3, seed=1)
        z = _logits(np.random.default_rng(10))
        allowed = set(np.argsort(z)[-3:].tolist())
        assert {eng._select(z) for _ in range(200)} <= allowed


class TestPrefillCapacityDefault:
    def test_default_covers_every_reachable_bucket(self):
        from repro.serving.engine import _prefill_capacity

        # prompts pad to multiples of prefill_bucket, capped by max_len —
        # the default bound fits one jitted fn per reachable bucket
        assert _prefill_capacity(EngineConfig(max_len=256, prefill_bucket=32)) == 8
        assert _prefill_capacity(EngineConfig(max_len=1024, prefill_bucket=32)) == 32
        assert _prefill_capacity(EngineConfig(max_len=16, prefill_bucket=32)) == 1

    def test_explicit_bound_wins(self):
        from repro.serving.engine import _prefill_capacity

        assert _prefill_capacity(EngineConfig(max_len=1024, prefill_bucket=32, prefill_cache_size=4)) == 4


class TestPrefillCacheBounded:
    def _pair(self, capacity):
        # _prefill_fn only touches cfg/compute_dtype inside the (untraced)
        # closure and the cache — skip the heavy model setup; the engine stub
        # carries just enough state for _sync_cache_metrics
        ad = object.__new__(OpaqueModelAdapter)
        ad.cfg = None
        ad.compute_dtype = None
        ad.prefill_cache = LruCache(capacity)
        eng = object.__new__(ServeEngine)
        eng.adapter = ad
        eng._prefill_cache = ad.prefill_cache
        eng.metrics = {}
        return ad, eng

    def test_repeat_bucket_reuses_jitted_fn(self):
        ad, eng = self._pair(capacity=4)
        f32 = ad._prefill_fn(32)
        assert ad._prefill_fn(32) is f32
        eng._sync_cache_metrics()
        assert eng.metrics["prefill_cache_size"] == 1
        assert eng.metrics["prefill_cache_evictions"] == 0
        # uniform hit accounting: the engine surfaces LruCache's own
        # hits/hit_rate, same numbers CompiledModel.cache_stats reports
        assert eng.metrics["prefill_cache_hits"] == 1
        assert eng.metrics["prefill_cache_hit_rate"] == ad.prefill_cache.hit_rate == 0.5

    def test_lru_eviction_and_metrics(self):
        ad, eng = self._pair(capacity=2)
        f32 = ad._prefill_fn(32)
        ad._prefill_fn(64)
        ad._prefill_fn(96)  # evicts bucket 32
        eng._sync_cache_metrics()
        assert eng.metrics["prefill_cache_size"] == 2
        assert eng.metrics["prefill_cache_evictions"] == 1
        assert 32 not in ad.prefill_cache
        assert ad._prefill_fn(32) is not f32  # rebuilt after eviction
