"""AOT plan artifacts: save/load round-trips that survive a process boundary.

The contract under test: ``load_artifact`` rebuilds a served compiled model
**without re-running passes, fusion or lowering** (no ``compile.fuse`` /
``compile.lower`` span ever fires on load), pre-seeds the plan cache with the
hot scenario cells recorded at save (so serving the recorded traffic
specializes nothing new), and round-trips provenance — including the
``[tuned]`` source tags on measured tile choices.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.backend.artifact import (
    ARTIFACT_SCHEMA,
    load_artifact,
    save_artifact,
    sidecar_path,
)
from repro.backend.plan import bindings_key
from repro.core.compile import compile_model
from repro.core.toolchain import MLPSpec, quantize_mlp
from repro.obs import trace as _trace


def _mlp_model(seed=21, name="aot_mlp"):
    rng = np.random.default_rng(seed)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(16, 32)).astype(np.float32) * 0.2,
            rng.normal(size=(32, 8)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(32,)).astype(np.float32) * 0.1,
            rng.normal(size=(8,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(64, 16)).astype(np.float32)
    return quantize_mlp(spec, calib, name=name), rng


def _seq_model():
    """A ('N', 'S', 16) two-axis model: the artifact's hot cells live on a
    (batch bucket x seq bucket) grid, not a single free axis."""
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(31)
    p = quant.quantize_linear_layer(
        rng.normal(size=(16, 8)).astype(np.float32) * 0.2,
        rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    gb = pqir.GraphBuilder("aot_seq")
    x = gb.add_input("x", "int8", ("N", "S", 16))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", ("N", "S", 8))
    return gb.build(), rng


class TestRoundTrip:
    @pytest.mark.parametrize("backend", ["ref", "interpret"])
    def test_bit_exact_across_the_grid(self, tmp_path, backend):
        """Outputs from a loaded artifact match a fresh compile bit-for-bit,
        on recorded cells and on cells the load never saw."""
        model, rng = _seq_model()
        cm = compile_model(model, backend=backend, dynamic_axes={"N": None, "S": 8})
        inp = cm.input_names[0]
        feeds = {
            (n, s): rng.integers(-128, 128, (n, s, 16)).astype(np.int8)
            for n, s in [(2, 5), (4, 8), (2, 13)]
        }
        for x in feeds.values():
            cm.run({inp: x})
        path = str(tmp_path / "seq.json")
        save_artifact(cm, path)

        loaded = load_artifact(path)
        fresh = compile_model(
            _seq_model()[0], backend=backend, dynamic_axes={"N": None, "S": 8}
        )
        # recorded cells + one cell ((8, 24) grid point) neither model has seen
        feeds[(8, 24)] = rng.integers(-128, 128, (8, 24, 16)).astype(np.int8)
        for x in feeds.values():
            got = loaded.run({inp: x})
            want = fresh.run({inp: x})
            assert set(got) == set(want)
            for k in want:
                np.testing.assert_array_equal(got[k], want[k])

    def test_model_and_plan_structure_survive(self, tmp_path):
        model, rng = _mlp_model()
        cm = compile_model(model, backend="ref", batch="dynamic")
        cm.run({cm.input_names[0]: rng.integers(-128, 128, (4, 16)).astype(np.int8)})
        path = str(tmp_path / "mlp.json")
        save_artifact(cm, path)
        loaded = load_artifact(path)
        assert loaded.input_names == cm.input_names
        assert loaded.output_names == cm.output_names
        assert loaded.plan.backend == cm.plan.backend
        assert loaded.plan.num_slots == cm.plan.num_slots
        assert loaded.plan.axes == cm.plan.axes
        assert len(loaded.plan.steps) == len(cm.plan.steps)
        for a, b in zip(loaded.plan.steps, cm.plan.steps):
            assert (a.kernel, a.kind, a.name) == (b.kernel, b.kind, b.name)
            assert a.out_slots == b.out_slots and a.outputs == b.outputs
            assert set(a.params) == set(b.params)
        assert loaded.stats == cm.stats
        assert loaded.axis_specs == cm.axis_specs
        assert loaded.plan_cache_capacity == cm.plan_cache_capacity

    def test_save_returns_path_and_writes_sidecar(self, tmp_path):
        model, rng = _mlp_model()
        cm = compile_model(model, backend="ref", batch="dynamic")
        path = str(tmp_path / "a.json")
        assert save_artifact(cm, path) == path
        assert (tmp_path / "a.npz").exists()
        assert sidecar_path("x/y.json") == "x/y.npz"
        assert sidecar_path("bare") == "bare.npz"


class TestWarmStart:
    def test_load_emits_no_fuse_or_lower_span(self, tmp_path):
        """The acceptance gate: zero re-compilation on load.  Only
        backend.specialize fires (one per pre-seeded cell)."""
        model, rng = _mlp_model()
        cm = compile_model(model, backend="ref", batch="dynamic")
        inp = cm.input_names[0]
        for n in (2, 8):
            cm.run({inp: rng.integers(-128, 128, (n, 16)).astype(np.int8)})
        path = str(tmp_path / "warm.json")
        save_artifact(cm, path)

        tracer = _trace.install()
        try:
            loaded = load_artifact(path)
        finally:
            _trace.uninstall()
        assert tracer.spans("compile.fuse") == []
        assert tracer.spans("compile.lower") == []
        assert len(tracer.spans("backend.specialize")) == 2

    def test_recorded_cells_serve_with_zero_new_specializations(self, tmp_path):
        model, rng = _mlp_model()
        cm = compile_model(model, backend="ref", batch="dynamic")
        inp = cm.input_names[0]
        xs = [rng.integers(-128, 128, (n, 16)).astype(np.int8) for n in (2, 4, 8)]
        for x in xs:
            cm.run({inp: x})
        path = str(tmp_path / "seeded.json")
        save_artifact(cm, path)

        loaded = load_artifact(path)
        # pre-seeding is by put, not get: the counters start clean
        assert loaded.cache_stats["hits"] == 0 and loaded.cache_stats["misses"] == 0
        assert sorted(loaded.plan_cache.keys()) == [
            bindings_key({"N": n}) for n in (2, 4, 8)
        ]
        for x in xs:
            loaded.run({inp: x})
        stats = loaded.cache_stats
        assert stats["misses"] == 0  # nothing re-specialized
        assert stats["hits"] == len(xs)
        # an unrecorded cell still specializes lazily, exactly once
        loaded.run({inp: rng.integers(-128, 128, (16, 16)).astype(np.int8)})
        assert loaded.cache_stats["misses"] == 1

    def test_warm_true_primes_the_jit_traces(self, tmp_path):
        model, rng = _mlp_model()
        cm = compile_model(model, backend="ref", batch="dynamic")
        inp = cm.input_names[0]
        cm.run({inp: rng.integers(-128, 128, (4, 16)).astype(np.int8)})
        path = str(tmp_path / "jit.json")
        save_artifact(cm, path)
        loaded = load_artifact(path, warm=True)
        out = loaded.run({inp: rng.integers(-128, 128, (4, 16)).astype(np.int8)})
        assert loaded.cache_stats == {
            **loaded.cache_stats, "hits": 1, "misses": 0
        }
        assert out[loaded.output_names[0]].shape == (4, 8)


class TestProvenance:
    def test_passes_and_fusions_carry_over_verbatim(self, tmp_path):
        model, rng = _mlp_model()
        cm = compile_model(model, backend="ref", batch="dynamic")
        inp = cm.input_names[0]
        cm.run({inp: rng.integers(-128, 128, (4, 16)).astype(np.int8)})
        path = str(tmp_path / "prov.json")
        save_artifact(cm, path)
        loaded = load_artifact(path)
        want = cm.plan.provenance.to_dict()
        got = loaded.plan.provenance.to_dict()
        assert got["passes"] == want["passes"]
        assert got["fusions"] == want["fusions"]
        # the live record re-accumulates the hot cells as they are re-seeded
        assert [ev["bindings"] for ev in got["specializations"]] == [
            ev["bindings"] for ev in want["specializations"]
        ]
        # the artifact JSON itself retains the saved history verbatim
        # (up to JSON's tuple -> list normalization)
        doc = json.load(open(path))
        assert doc["provenance"] == json.loads(json.dumps(want))

    def test_tuned_tile_tags_round_trip(self, tmp_path):
        """Tiles picked by a measured search must come back `[tuned]`, with
        the tuned bk/bn choice itself — not the heuristic's."""
        from repro.backend import cost
        from repro.backend.autotune import Autotuner

        rng = np.random.default_rng(17)
        spec = MLPSpec(
            weights=[rng.normal(0, 0.4, (256, 256)).astype(np.float32) for _ in range(2)],
            biases=[rng.normal(0, 0.2, (256,)).astype(np.float32) for _ in range(2)],
            activations=["Relu", None],
        )
        calib = rng.normal(0, 1.0, (64, 256)).astype(np.float32)
        model = quantize_mlp(spec, calib, name="tuned_aot")

        def measure(step, shape, backend):
            return cost.qmatmul_tile_cost(
                shape["m"], shape["k"], shape["n"], shape["bm"], shape["bk"], shape["bn"]
            )

        tuner = Autotuner(measure_fn=measure)
        cm = compile_model(model, backend="interpret", batch="dynamic", autotune=tuner)
        inp = cm.input_names[0]
        cm.run({inp: rng.integers(-128, 128, (8, 256)).astype(np.int8)})
        key = bindings_key({"N": 8})
        plan, _ = cm.plan_cache.peek(key)
        tuned_tiles = {
            s.name: (s.params["shape"]["bm"], s.params["shape"]["bk"], s.params["shape"]["bn"])
            for s in plan.steps
            if isinstance(s.params.get("shape"), dict) and "bm" in s.params["shape"]
        }
        assert tuned_tiles  # the 256-wide MLP has a real tile lattice

        path = str(tmp_path / "tuned.json")
        save_artifact(cm, path)
        doc = json.load(open(path))
        by_cell = {tuple(sorted(c["bindings"].items())): c["tiles"] for c in doc["cells"]}
        recs = by_cell[(("N", 8),)]
        assert set(recs) == set(tuned_tiles)
        for name, rec in recs.items():
            assert rec["source"] == "tuned"
            assert (rec["bm"], rec["bk"], rec["bn"]) == tuned_tiles[name]

        loaded = load_artifact(path)
        lplan, _ = loaded.plan_cache.peek(key)
        got_tiles = {
            s.name: (s.params["shape"]["bm"], s.params["shape"]["bk"], s.params["shape"]["bn"])
            for s in lplan.steps
            if isinstance(s.params.get("shape"), dict) and "bm" in s.params["shape"]
        }
        assert got_tiles == tuned_tiles
        ev = loaded.plan.provenance.specializations[-1]
        assert ev.tiles and all("[tuned]" in rec for _, rec in ev.tiles)
        # and the tuned-tile plan still serves bit-exactly
        x = rng.integers(-128, 128, (8, 256)).astype(np.int8)
        np.testing.assert_array_equal(
            loaded.run({inp: x})[loaded.output_names[0]],
            cm.run({inp: x})[cm.output_names[0]],
        )


class TestRejection:
    def _saved(self, tmp_path):
        model, rng = _mlp_model()
        cm = compile_model(model, backend="ref", batch="dynamic")
        cm.run({cm.input_names[0]: rng.integers(-128, 128, (2, 16)).astype(np.int8)})
        path = str(tmp_path / "r.json")
        save_artifact(cm, path)
        return path

    def test_schema_version_mismatch_rejected(self, tmp_path):
        path = self._saved(tmp_path)
        doc = json.load(open(path))
        doc["schema"] = "repro-plan-v0"
        json.dump(doc, open(path, "w"))
        with pytest.raises(ValueError, match="schema"):
            load_artifact(path)

    def test_missing_schema_rejected(self, tmp_path):
        path = self._saved(tmp_path)
        doc = json.load(open(path))
        del doc["schema"]
        json.dump(doc, open(path, "w"))
        with pytest.raises(ValueError, match="schema"):
            load_artifact(path)

    def test_corrupt_json_rejected(self, tmp_path):
        path = self._saved(tmp_path)
        with open(path, "w") as f:
            f.write('{"schema": "repro-plan-v1", "plan": {')
        with pytest.raises(ValueError, match="corrupt"):
            load_artifact(path)

    def test_sidecar_digest_mismatch_rejected(self, tmp_path):
        path = self._saved(tmp_path)
        npz = sidecar_path(path)
        with open(npz, "ab") as f:
            f.write(b"\x00")  # truncation and tampering look the same: bad digest
        with pytest.raises(ValueError, match="digest"):
            load_artifact(path)

    def test_missing_sidecar_rejected(self, tmp_path):
        import os

        path = self._saved(tmp_path)
        os.unlink(sidecar_path(path))
        with pytest.raises(ValueError, match="sidecar"):
            load_artifact(path)

    def test_callable_bucketing_policy_rejected_at_save(self, tmp_path):
        model, _ = _mlp_model()
        cm = compile_model(
            model, backend="ref", dynamic_axes={"N": lambda n: max(1, n)}
        )
        with pytest.raises(ValueError, match="callable"):
            save_artifact(cm, str(tmp_path / "cb.json"))


class TestPlanDiff:
    def _save(self, tmp_path, tag, batches):
        model, rng = _mlp_model(name="diffed")
        cm = compile_model(model, backend="interpret", batch="dynamic")
        inp = cm.input_names[0]
        for n in batches:
            cm.run({inp: rng.integers(-128, 128, (n, 16)).astype(np.int8)})
        path = str(tmp_path / f"{tag}.json")
        save_artifact(cm, path)
        return path

    def _diff(self, a, b):
        return subprocess.run(
            [sys.executable, "scripts/plan_diff.py", a, b],
            capture_output=True, text=True, cwd="/root/repo",
        )

    def test_self_diff_is_identical(self, tmp_path):
        a = self._save(tmp_path, "a", (2, 8))
        r = self._diff(a, a)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "structurally identical" in r.stdout

    def test_cell_set_change_is_structural(self, tmp_path):
        a = self._save(tmp_path, "a", (2, 8))
        b = self._save(tmp_path, "b", (2, 16))
        r = self._diff(a, b)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "STRUCTURALLY DIFFERENT" in r.stdout
        assert "N=8" in r.stdout and "N=16" in r.stdout

    def test_non_artifact_input_rejected(self, tmp_path):
        bad = tmp_path / "x.json"
        bad.write_text('{"schema": "other"}')
        r = self._diff(str(bad), str(bad))
        assert r.returncode == 2
