"""Hardened Graph.validate(): duplicate/undefined tensor names are rejected
instead of silently accepted, independent of node-list order."""
import numpy as np
import pytest

from repro.core import pqir


def _linear_graph():
    gb = pqir.GraphBuilder("g")
    x = gb.add_input("x", "float32", (None, 4))
    c = gb.add_initializer("c", np.float32(2.0))
    y = gb.op("Mul", [x, c], out_hint="y")
    gb.add_output(y, "float32", (None, 4))
    return gb.build(validate=False)


class TestValidateHardening:
    def test_valid_graph_passes(self):
        _linear_graph().validate()

    def test_duplicate_graph_input(self):
        m = _linear_graph()
        m.graph.inputs.append(pqir.TensorInfo("x", "float32", (None, 4)))
        with pytest.raises(ValueError, match="duplicate graph input"):
            m.validate()

    def test_input_shadowing_initializer(self):
        m = _linear_graph()
        m.graph.inputs.append(pqir.TensorInfo("c", "float32", ()))
        with pytest.raises(ValueError, match="shadows an initializer"):
            m.validate()

    def test_duplicate_graph_output(self):
        m = _linear_graph()
        m.graph.outputs.append(pqir.TensorInfo(m.graph.outputs[0].name, "float32", (None, 4)))
        with pytest.raises(ValueError, match="duplicate graph output"):
            m.validate()

    def test_undefined_node_input(self):
        m = _linear_graph()
        m.graph.nodes[0].inputs[0] = "ghost"
        with pytest.raises(ValueError, match="undefined tensor 'ghost'"):
            m.validate()

    def test_tensor_produced_twice(self):
        m = _linear_graph()
        y = m.graph.nodes[0].outputs[0]
        m.graph.nodes.append(pqir.Node("Relu", ["x"], [y], name="dup"))
        with pytest.raises(ValueError, match="produced twice"):
            m.validate()

    def test_forward_reference_is_legal(self):
        """Validation is order-independent: a topologically-valid graph whose
        node list is reversed still validates (toposorted() fixes execution)."""
        gb = pqir.GraphBuilder("g")
        x = gb.add_input("x", "float32", (None, 4))
        a = gb.op("Relu", [x], out_hint="a")
        b = gb.op("Sqrt", [a], out_hint="b")
        gb.add_output(b, "float32", (None, 4))
        m = gb.build()
        m.graph.nodes.reverse()
        m.validate()

    def test_cycle_rejected(self):
        gb = pqir.GraphBuilder("g")
        gb.add_input("x", "float32", (None, 4))
        gb.add_node("Relu", ["b"], ["a"], name="n1")
        gb.add_node("Relu", ["a"], ["b"], name="n2")
        gb.add_output("b", "float32", (None, 4))
        m = gb.build(validate=False)
        with pytest.raises(ValueError, match="cycle"):
            m.validate()
