"""Sharded replica router: cell affinity, failover, fleet accounting.

The contract under test: N replicas warm-started from one AOT artifact serve
through one front door; distinct sequence-bucket cells stick to distinct
replicas (so each replica's plan cache stays hot); a replica failure migrates
its queue in order onto a healthy replica with zero lost and zero duplicated
requests; and all replicas publish into one shared metrics registry.
"""
import numpy as np
import pytest

from repro.backend.artifact import save_artifact
from repro.core.compile import compile_model
from repro.core.runtime import ReferenceRuntime
from repro.core.toolchain import MLPSpec, quantize_mlp
from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry
from repro.serving import (
    CompiledModelServer,
    CompiledServerConfig,
    RouterConfig,
    ShardedRouter,
)


def _batch_model(name="fleet_mlp"):
    rng = np.random.default_rng(21)
    spec = MLPSpec(
        weights=[
            rng.normal(size=(16, 32)).astype(np.float32) * 0.2,
            rng.normal(size=(32, 8)).astype(np.float32) * 0.2,
        ],
        biases=[
            rng.normal(size=(32,)).astype(np.float32) * 0.1,
            rng.normal(size=(8,)).astype(np.float32) * 0.1,
        ],
        activations=["Relu", None],
    )
    calib = rng.normal(size=(64, 16)).astype(np.float32)
    return quantize_mlp(spec, calib, name=name), rng


def _seq_model():
    from repro.core import patterns, pqir, quant

    rng = np.random.default_rng(31)
    p = quant.quantize_linear_layer(
        rng.normal(size=(16, 8)).astype(np.float32) * 0.2,
        rng.normal(size=(8,)).astype(np.float32) * 0.1, 0.05, 0.1,
    )
    gb = pqir.GraphBuilder("fleet_seq")
    x = gb.add_input("x", "int8", ("N", "S", 16))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=True, activation="Relu")
    gb.add_output(y, "int8", ("N", "S", 8))
    return gb.build(), rng


def _seq_artifact(tmp_path, warm_lens=(4, 12, 20)):
    """Save a two-axis artifact whose hot cells cover the seq buckets the
    tests route on (batch bucket 4 x seq buckets 8/16/24)."""
    model, rng = _seq_model()
    cm = compile_model(model, backend="ref", dynamic_axes={"N": None, "S": 8})
    srv = CompiledModelServer(cm, CompiledServerConfig(max_batch=4))
    for s in warm_lens:
        for _ in range(4):
            srv.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
        srv.step()
    path = str(tmp_path / "fleet_seq.json")
    save_artifact(cm, path)
    return model, path, rng


def _batch_artifact(tmp_path):
    model, rng = _batch_model()
    cm = compile_model(model, backend="ref", batch="dynamic")
    inp = cm.input_names[0]
    for n in (4, 8):
        cm.run({inp: rng.integers(-128, 128, (n, 16)).astype(np.int8)})
    path = str(tmp_path / "fleet_mlp.json")
    save_artifact(cm, path)
    return model, path, rng


class TestCellAffinity:
    def test_distinct_seq_cells_land_on_distinct_replicas(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=3, server_cfg=CompiledServerConfig(max_batch=4), warm=False
        )
        lens_by_cell = {8: 4, 12: 4, 20: 4}  # buckets 8, 16, 24
        reqs = []
        for s, n in lens_by_cell.items():
            for _ in range(n):
                reqs.append(router.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8)))
        done = router.run_until_drained()
        assert len(done) == 12 and all(r.done for r in reqs)
        s = router.summary()
        # three cells, three replicas: least-loaded placement spreads them 1:1
        assert sorted(s["cell_owners"]) == ["S=16", "S=24", "S=8"]
        assert len(set(s["cell_owners"].values())) == 3
        # every replica served only its own (pre-seeded) cell: no misses
        for name, rep_summary in s["replicas"].items():
            assert rep_summary["plan_cache"]["misses"] == 0, name
        assert all(rate == 1.0 for rate in s["plan_cache_hit_rates"].values())
        assert s["lost"] == 0 and s["duplicates"] == 0

    def test_cells_are_sticky_across_waves(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=2, server_cfg=CompiledServerConfig(max_batch=4), warm=False
        )
        for _ in range(3):  # three waves on the same two cells
            for s in (4, 12):
                for _ in range(4):
                    router.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
            router.run_until_drained()
        owners = router.summary()["cell_owners"]
        assert set(owners) == {"S=8", "S=16"}
        assert len(set(owners.values())) == 2  # still one cell per replica
        for rep in router.replicas:
            assert rep.server.metrics["batches"] == 3  # its cell's waves only

    def test_results_bit_exact_per_request(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        rt = ReferenceRuntime(model)
        router = ShardedRouter.from_artifact(
            path, replicas=3, server_cfg=CompiledServerConfig(max_batch=4), warm=False
        )
        lens = [3, 12, 20, 7, 18, 4, 23, 9]
        reqs = [
            router.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8)) for s in lens
        ]
        router.run_until_drained()
        out = "fc0_q"
        out_name = next(iter(reqs[0].outputs))
        for r, s in zip(reqs, lens):
            assert r.done and r.outputs[out_name].shape == (s, 8)
            solo = rt.run({"x": r.inner.x[None, :, :]})[out_name][0]
            np.testing.assert_array_equal(r.outputs[out_name], solo, err_msg=f"uid {r.uid}")

    def test_batch_only_traffic_is_single_cell(self, tmp_path):
        """With no sequence axis there is only the empty cell: all traffic
        sticks to one replica (by design — the batch bucket emerges only at
        coalescing time, so there is nothing to shard on)."""
        model, path, rng = _batch_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=2, server_cfg=CompiledServerConfig(max_batch=8), warm=False
        )
        for _ in range(8):
            router.submit(rng.integers(-128, 128, (16,)).astype(np.int8))
        router.run_until_drained()
        s = router.summary()
        assert s["cell_owners"] == {"*": "r0"}
        assert s["completed"] == 8 and s["lost"] == 0

    def test_fleet_unique_uids(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=3, server_cfg=CompiledServerConfig(max_batch=4), warm=False
        )
        reqs = [
            router.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
            for s in (4, 12, 20) * 3
        ]
        assert len({r.uid for r in reqs}) == len(reqs)
        replicas_used = {r.replica for r in reqs}
        assert len(replicas_used) == 3  # uid spaces from three strided counters


class TestFailover:
    def test_failed_replica_queue_migrates_in_order(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=2,
            server_cfg=CompiledServerConfig(max_batch=4),
            cfg=RouterConfig(failure_threshold=1),
            warm=False,
        )
        # two cells, one per replica
        a = [router.submit(rng.integers(-128, 128, (4, 16)).astype(np.int8)) for _ in range(4)]
        b = [router.submit(rng.integers(-128, 128, (12, 16)).astype(np.int8)) for _ in range(4)]
        victim = router.replicas[router._cell_owner[a[0].cell]]
        survivor = next(r for r in router.replicas if r is not victim)
        victim.server.cm.run = lambda feeds: (_ for _ in ()).throw(RuntimeError("replica down"))

        expect_order = [r.uid for r in victim.server.queue]
        done = router.run_until_drained()
        s = router.summary()
        assert len(done) == 8 and all(r.done for r in a + b)
        assert s["lost"] == 0 and s["duplicates"] == 0
        assert s["failovers"] == 1 and s["rerouted"] == 4
        assert not victim.healthy and survivor.healthy
        # the migrated requests kept their order and their handles track the
        # new owner
        migrated = [r for r in a if r.rerouted]
        assert [r.uid for r in migrated] == expect_order
        assert all(r.replica == survivor.name for r in migrated)
        # the failed replica's cell now points at the survivor
        assert set(s["cell_owners"].values()) == {survivor.name}
        assert s["health"][victim.name]["healthy"] is False
        assert s["registry"][f"fleet.failures.{victim.name}"] == 1

    def test_below_threshold_failure_retries_in_place(self, tmp_path):
        """A transient failure (threshold not reached) keeps the queue on the
        replica — the batch is retried there, in order, once it recovers."""
        model, path, rng = _batch_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=1,
            server_cfg=CompiledServerConfig(max_batch=8),
            cfg=RouterConfig(failure_threshold=3),
            warm=False,
        )
        reqs = [router.submit(rng.integers(-128, 128, (16,)).astype(np.int8)) for _ in range(4)]
        rep = router.replicas[0]
        real_run = rep.server.cm.run
        rep.server.cm.run = lambda feeds: (_ for _ in ()).throw(RuntimeError("transient"))
        assert router.step() == []
        assert rep.healthy and rep.failures == 1
        assert [r.uid for r in rep.server.queue] == [r.uid for r in reqs]
        rep.server.cm.run = real_run
        done = router.run_until_drained()
        assert len(done) == 4 and rep.failures == 0
        assert router.summary()["lost"] == 0

    def test_new_submissions_avoid_the_dead_replica(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=2,
            server_cfg=CompiledServerConfig(max_batch=4),
            cfg=RouterConfig(failure_threshold=1),
            warm=False,
        )
        r1 = router.submit(rng.integers(-128, 128, (4, 16)).astype(np.int8))
        victim = router.replicas[router._cell_owner[r1.cell]]
        victim.server.cm.run = lambda feeds: (_ for _ in ()).throw(RuntimeError("down"))
        # this cycle kills the victim and migrates its queue — the survivor
        # may serve the migrated request within the same fleet cycle
        done = router.step()
        r2 = router.submit(rng.integers(-128, 128, (4, 16)).astype(np.int8))
        assert r2.replica != victim.name
        done += router.run_until_drained()
        assert len(done) == 2 and r1.done and r2.done

    def test_last_replica_failing_raises(self, tmp_path):
        model, path, rng = _batch_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=1,
            cfg=RouterConfig(failure_threshold=1),
            warm=False,
        )
        router.submit(rng.integers(-128, 128, (16,)).astype(np.int8))
        router.replicas[0].server.cm.run = (
            lambda feeds: (_ for _ in ()).throw(RuntimeError("down"))
        )
        with pytest.raises(RuntimeError, match="no healthy replica"):
            router.step()
        with pytest.raises(RuntimeError, match="no healthy replica"):
            router.submit(rng.integers(-128, 128, (16,)).astype(np.int8))


class TestFleetObservability:
    def test_one_registry_aggregates_all_replicas(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        registry = MetricsRegistry()
        router = ShardedRouter.from_artifact(
            path, replicas=3,
            server_cfg=CompiledServerConfig(max_batch=4),
            registry=registry, warm=False,
        )
        for s in (4, 12, 20):
            for _ in range(4):
                router.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
        router.run_until_drained()
        snap = registry.snapshot()
        # counters are shared: per-replica serve.* increments sum fleet-wide
        assert snap["serve.requests"] == 12 and snap["serve.completed"] == 12
        assert snap["fleet.requests"] == 12 and snap["fleet.completed"] == 12
        assert snap["serve.latency_ms"]["count"] == 12
        total_batches = sum(
            r.server.metrics["batches"] for r in router.replicas
        )
        assert snap["serve.batches"] == total_batches == 3

    def test_replica_spans_carry_the_replica_attribute(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=2, server_cfg=CompiledServerConfig(max_batch=4), warm=False
        )
        tracer = _trace.install()
        try:
            for s in (4, 12):
                for _ in range(4):
                    router.submit(rng.integers(-128, 128, (s, 16)).astype(np.int8))
            router.run_until_drained()
        finally:
            _trace.uninstall()
        steps = tracer.spans("serve.step")
        assert steps and all("replica" in sp.attrs for sp in steps)
        assert {sp.attrs["replica"] for sp in steps} == {"r0", "r1"}

    def test_health_surfaces_straggler_state(self, tmp_path):
        model, path, rng = _batch_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=1, server_cfg=CompiledServerConfig(max_batch=8), warm=False
        )
        for _ in range(8):
            router.submit(rng.integers(-128, 128, (16,)).astype(np.int8))
        router.run_until_drained()
        h = router.health()["r0"]
        assert h["healthy"] and h["steps"] >= 1 and h["queue"] == 0
        assert h["step_time_ewma_s"] is None or h["step_time_ewma_s"] >= 0.0
        assert isinstance(h["straggler_steps"], list)


class TestConstruction:
    def test_rejects_empty_fleet_and_bad_config(self, tmp_path):
        with pytest.raises(ValueError, match="at least one replica"):
            ShardedRouter([])
        with pytest.raises(ValueError, match="replicas"):
            model, path, rng = _batch_artifact(tmp_path)
            ShardedRouter.from_artifact(path, replicas=0)
        with pytest.raises(ValueError, match="failure_threshold"):
            RouterConfig(failure_threshold=0)

    def test_rejects_duplicate_replica_names(self, tmp_path):
        model, path, rng = _batch_artifact(tmp_path)
        from repro.backend.artifact import load_artifact

        servers = [
            CompiledModelServer(load_artifact(path), name="same")
            for _ in range(2)
        ]
        with pytest.raises(ValueError, match="unique"):
            ShardedRouter(servers)

    def test_rejects_mixed_artifact_shapes(self, tmp_path):
        _, bpath, _ = _batch_artifact(tmp_path)
        _, spath, _ = _seq_artifact(tmp_path)
        from repro.backend.artifact import load_artifact

        servers = [
            CompiledModelServer(load_artifact(bpath), name="a"),
            CompiledModelServer(load_artifact(spath), name="b"),
        ]
        with pytest.raises(ValueError, match="same artifact shape"):
            ShardedRouter(servers)

    def test_warm_start_replicas_preseed_every_cache(self, tmp_path):
        model, path, rng = _seq_artifact(tmp_path)
        router = ShardedRouter.from_artifact(
            path, replicas=2, server_cfg=CompiledServerConfig(max_batch=4), warm=True
        )
        for rep in router.replicas:
            stats = rep.server.cm.cache_stats
            assert stats["size"] == 3  # the three recorded cells
            assert stats["hits"] == 0 and stats["misses"] == 0
