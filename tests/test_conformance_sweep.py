"""E6: compiler-vs-reference conformance sweep over every generic lowering,
plus the per-channel differential sweep over every *fused* kernel.

One randomized case per op in the compiler's ``_JOPS`` table, executed by
both :mod:`repro.core.runtime` (the oracle) and the compiled generic path
(``fuse=False, optimize=False`` — pure ``op.<Name>`` registry kernels).
Integer outputs must match bit-exactly; float outputs allclose.  The
parametrization is driven by ``_JOPS`` itself, so adding a generic lowering
without a sweep case fails loudly.

``TestPerChannelFusedSweep`` is the differential conformance harness for the
axis-aware lowering: per-channel variants of every fused requant kernel
(qlinear matmul two-Mul/one-Mul, uint8 activations, the Gemm-codified form,
conv, and the LUT composition) compiled on every registered backend — ``ref``
and ``interpret``; ``interpret`` *is* the Pallas kernel run in interpret mode,
so the ``pallas`` backend differs only by ``interpret=False`` at dispatch —
and asserted bit-exact against the reference runtime.
"""
import numpy as np
import pytest

from repro.core import patterns, pqir, quant
from repro.core.compile import _JOPS, compile_model
from repro.core.runtime import ReferenceRuntime

#: Backends every fused case is swept across.  "interpret" executes the same
#: Pallas tile kernels as "pallas", in the Pallas interpreter (CPU-hosted
#: CI); real-TPU pallas coverage is the ROADMAP CI-lane follow-up.
BACKENDS = ("ref", "interpret")


def _g(name):
    return pqir.GraphBuilder(name)


def _finish(gb, y, dtype, shape=None):
    gb.add_output(y, dtype, shape if shape is not None else (None,))
    return gb.build()


def _rngf(rng, shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


def _rng8(rng, shape, lo=-128, hi=128):
    return rng.integers(lo, hi, shape).astype(np.int8)


# Each case: rng → (model, feeds).  Inputs are random graph inputs; shape
# parameters (Reshape target, Slice starts/ends, axes, …) are initializers,
# matching how real artifacts codify them.


def _case_matmul_integer(rng):
    gb = _g("m")
    a = gb.add_input("a", "int8", (4, 8))
    b = gb.add_input("b", "int8", (8, 6))
    azp = gb.add_initializer("azp", np.int8(3))
    bzp = gb.add_initializer("bzp", np.int8(-2))
    y = gb.op("MatMulInteger", [a, b, azp, bzp])
    return _finish(gb, y, "int32"), {"a": _rng8(rng, (4, 8)), "b": _rng8(rng, (8, 6))}


def _case_conv_integer(rng):
    gb = _g("c")
    x = gb.add_input("x", "int8", (2, 3, 8, 8))
    w = gb.add_input("w", "int8", (4, 3, 3, 3))
    y = gb.op("ConvInteger", [x, w], pads=(1, 1, 1, 1), strides=(1, 1))
    return _finish(gb, y, "int32"), {"x": _rng8(rng, (2, 3, 8, 8)), "w": _rng8(rng, (4, 3, 3, 3))}


def _case_quantize_linear(rng):
    gb = _g("q")
    x = gb.add_input("x", "float32", (4, 8))
    s = gb.add_initializer("s", np.float32(0.05))
    zp = gb.add_initializer("zp", np.int8(5))
    y = gb.op("QuantizeLinear", [x, s, zp])
    return _finish(gb, y, "int8"), {"x": _rngf(rng, (4, 8))}


def _case_dequantize_linear(rng):
    gb = _g("dq")
    x = gb.add_input("x", "int8", (4, 8))
    s = gb.add_initializer("s", np.float32(0.05))
    zp = gb.add_initializer("zp", np.int8(3))
    y = gb.op("DequantizeLinear", [x, s, zp])
    return _finish(gb, y, "float32"), {"x": _rng8(rng, (4, 8))}


def _case_cast(rng):
    gb = _g("cast")
    x = gb.add_input("x", "float32", (4, 8))
    y = gb.op("Cast", [x], to="float16")
    return _finish(gb, y, "float16"), {"x": _rngf(rng, (4, 8))}


def _binary(op):
    def build(rng):
        gb = _g(op.lower())
        a = gb.add_input("a", "float32", (4, 8))
        b = gb.add_input("b", "float32", (4, 8))
        y = gb.op(op, [a, b])
        return _finish(gb, y, "float32"), {"a": _rngf(rng, (4, 8)), "b": _rngf(rng, (4, 8))}

    return build


def _case_div(rng):
    gb = _g("div")  # integer path: floor division must match exactly
    a = gb.add_input("a", "int32", (4, 8))
    b = gb.add_input("b", "int32", (4, 8))
    y = gb.op("Div", [a, b])
    return _finish(gb, y, "int32"), {
        "a": rng.integers(-100, 100, (4, 8)).astype(np.int32),
        "b": rng.integers(1, 6, (4, 8)).astype(np.int32),
    }


def _unary(op, positive=False):
    def build(rng):
        gb = _g(op.lower())
        x = gb.add_input("x", "float32", (4, 8))
        y = gb.op(op, [x])
        xv = _rngf(rng, (4, 8))
        if positive:
            xv = np.abs(xv) + 0.1
        return _finish(gb, y, "float32"), {"x": xv}

    return build


def _case_pow(rng):
    gb = _g("pow")
    a = gb.add_input("a", "float32", (4, 8))
    e = gb.add_initializer("e", np.float32(1.7))
    y = gb.op("Pow", [a, e])
    return _finish(gb, y, "float32"), {"a": np.abs(_rngf(rng, (4, 8))) + 0.1}


def _case_clip(rng):
    gb = _g("clip")
    x = gb.add_input("x", "float32", (4, 8))
    lo = gb.add_initializer("lo", np.float32(-0.5))
    hi = gb.add_initializer("hi", np.float32(0.5))
    y = gb.op("Clip", [x, lo, hi])
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (4, 8))}


def _case_softmax(rng):
    gb = _g("sm")
    x = gb.add_input("x", "float32", (4, 8))
    y = gb.op("Softmax", [x], axis=-1)
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (4, 8))}


def _case_matmul(rng):
    gb = _g("mm")
    a = gb.add_input("a", "float32", (4, 8))
    b = gb.add_input("b", "float32", (8, 6))
    y = gb.op("MatMul", [a, b])
    return _finish(gb, y, "float32"), {"a": _rngf(rng, (4, 8)), "b": _rngf(rng, (8, 6))}


def _case_gemm(rng):
    gb = _g("gemm")
    a = gb.add_input("a", "float32", (4, 8))
    b = gb.add_input("b", "float32", (6, 8))
    c = gb.add_initializer("c", _rngf(rng, (6,)))
    y = gb.op("Gemm", [a, b, c], transB=1, alpha=0.5, beta=1.5)
    return _finish(gb, y, "float32"), {"a": _rngf(rng, (4, 8)), "b": _rngf(rng, (6, 8))}


def _case_reshape(rng):
    gb = _g("rs")
    x = gb.add_input("x", "float32", (4, 6))
    t = gb.add_initializer("t", np.asarray([2, 12], np.int64))
    y = gb.op("Reshape", [x, t])
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (4, 6))}


def _case_transpose(rng):
    gb = _g("tp")
    x = gb.add_input("x", "float32", (4, 6))
    y = gb.op("Transpose", [x], perm=[1, 0])
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (4, 6))}


def _case_flatten(rng):
    gb = _g("fl")
    x = gb.add_input("x", "float32", (2, 3, 4))
    y = gb.op("Flatten", [x], axis=1)
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (2, 3, 4))}


def _case_concat(rng):
    gb = _g("cc")
    a = gb.add_input("a", "float32", (2, 3))
    b = gb.add_input("b", "float32", (2, 5))
    y = gb.op("Concat", [a, b], axis=1)
    return _finish(gb, y, "float32"), {"a": _rngf(rng, (2, 3)), "b": _rngf(rng, (2, 5))}


def _case_slice(rng):
    gb = _g("sl")
    x = gb.add_input("x", "int32", (4, 10))
    st = gb.add_initializer("st", np.asarray([1], np.int64))
    en = gb.add_initializer("en", np.asarray([9], np.int64))
    ax = gb.add_initializer("ax", np.asarray([1], np.int64))
    sp = gb.add_initializer("sp", np.asarray([2], np.int64))
    y = gb.op("Slice", [x, st, en, ax, sp])
    return _finish(gb, y, "int32"), {"x": rng.integers(-50, 50, (4, 10)).astype(np.int32)}


def _case_gather(rng):
    gb = _g("ga")
    x = gb.add_input("x", "float32", (5, 4))
    idx = gb.add_initializer("idx", np.asarray([[0, 3], [2, 1]], np.int64))
    y = gb.op("Gather", [x, idx], axis=0)
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (5, 4))}


def _case_squeeze(rng):
    gb = _g("sq")
    x = gb.add_input("x", "int8", (2, 1, 3, 1))
    ax = gb.add_initializer("ax", np.asarray([1, 3], np.int64))
    y = gb.op("Squeeze", [x, ax])
    return _finish(gb, y, "int8"), {"x": _rng8(rng, (2, 1, 3, 1))}


def _case_unsqueeze(rng):
    gb = _g("us")
    x = gb.add_input("x", "int8", (2, 3))
    ax = gb.add_initializer("ax", np.asarray([0, 2], np.int64))
    y = gb.op("Unsqueeze", [x, ax])
    return _finish(gb, y, "int8"), {"x": _rng8(rng, (2, 3))}


def _pool(op):
    def build(rng):
        gb = _g(op.lower())
        x = gb.add_input("x", "float32", (2, 3, 8, 8))
        y = gb.op(op, [x], kernel_shape=(2, 2), strides=(2, 2))
        return _finish(gb, y, "float32"), {"x": _rngf(rng, (2, 3, 8, 8))}

    return build


def _case_gap(rng):
    gb = _g("gap")
    x = gb.add_input("x", "float32", (2, 3, 5, 5))
    y = gb.op("GlobalAveragePool", [x])
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (2, 3, 5, 5))}


def _case_reduce_mean(rng):
    gb = _g("rm")
    x = gb.add_input("x", "float32", (2, 3, 5))
    y = gb.op("ReduceMean", [x], axes=(1,), keepdims=1)
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (2, 3, 5))}


def _case_reduce_max(rng):
    # keepdims on the last axis — the attention max-subtract shape
    gb = _g("rmax")
    x = gb.add_input("x", "float32", (2, 4, 7))
    y = gb.op("ReduceMax", [x], axes=(2,), keepdims=1)
    return _finish(gb, y, "float32"), {"x": _rngf(rng, (2, 4, 7))}


def _case_reduce_sum(rng):
    # int32 accumulator reduction, as the attention probability normalizer
    gb = _g("rsum")
    x = gb.add_input("x", "int32", (2, 4, 7))
    y = gb.op("ReduceSum", [x], axes=(2,), keepdims=1)
    feeds = {"x": rng.integers(0, 255, (2, 4, 7)).astype(np.int32)}
    return _finish(gb, y, "int32"), feeds


CASES = {
    "MatMulInteger": _case_matmul_integer,
    "ConvInteger": _case_conv_integer,
    "QuantizeLinear": _case_quantize_linear,
    "DequantizeLinear": _case_dequantize_linear,
    "Cast": _case_cast,
    "Mul": _binary("Mul"),
    "Add": _binary("Add"),
    "Sub": _binary("Sub"),
    "Div": _case_div,
    "Relu": _unary("Relu"),
    "Tanh": _unary("Tanh"),
    "Sigmoid": _unary("Sigmoid"),
    "Erf": _unary("Erf"),
    "Sqrt": _unary("Sqrt", positive=True),
    "Pow": _case_pow,
    "Clip": _case_clip,
    "Softmax": _case_softmax,
    "MatMul": _case_matmul,
    "Gemm": _case_gemm,
    "Reshape": _case_reshape,
    "Transpose": _case_transpose,
    "Flatten": _case_flatten,
    "Concat": _case_concat,
    "Slice": _case_slice,
    "Gather": _case_gather,
    "Squeeze": _case_squeeze,
    "Unsqueeze": _case_unsqueeze,
    "MaxPool": _pool("MaxPool"),
    "AveragePool": _pool("AveragePool"),
    "GlobalAveragePool": _case_gap,
    "ReduceMean": _case_reduce_mean,
    "ReduceMax": _case_reduce_max,
    "ReduceSum": _case_reduce_sum,
}


@pytest.mark.parametrize("op", sorted(_JOPS))
def test_generic_lowering_matches_reference(op):
    assert op in CASES, f"op {op!r} has a generic lowering but no sweep case — add one"
    rng = np.random.default_rng(abs(hash(op)) % (2**31))
    model, feeds = CASES[op](rng)
    ref = ReferenceRuntime(model).run(feeds)
    cm = compile_model(model, fuse=False, optimize=False)
    assert cm.stats["generic"] >= 1
    got = cm.run(feeds)
    for k, want in ref.items():
        have = got[k]
        assert have.shape == want.shape, (op, have.shape, want.shape)
        assert have.dtype == want.dtype, (op, have.dtype, want.dtype)
        if np.issubdtype(want.dtype, np.integer) or want.dtype == np.bool_:
            np.testing.assert_array_equal(have, want, err_msg=op)
        else:
            np.testing.assert_allclose(have, want, rtol=1e-5, atol=1e-6, err_msg=op)


def _pc_params(rng, n_in, n_out, *, bias=True, out_dtype="int8"):
    """A per-channel-quantized FC layer with a deliberately hot channel, so
    per-tensor and per-channel scales genuinely differ."""
    w = rng.normal(size=(n_in, n_out)).astype(np.float32) * 0.2
    w[:, rng.integers(0, n_out)] *= 25.0
    b = rng.normal(size=(n_out,)).astype(np.float32) * 0.1 if bias else None
    return quant.quantize_linear_layer(w, b, 0.05, 0.1, per_channel=True, out_dtype=out_dtype)


def _pc_fc(rng, *, two_mul=True, activation=None, bias=True, in_dtype="int8", out_dtype="int8"):
    p = _pc_params(rng, 32, 24, bias=bias, out_dtype=out_dtype)
    gb = _g("pc_fc")
    x = gb.add_input("x", in_dtype, (None, 32))
    y = patterns.fc_layer(gb, x, p, "fc0", two_mul=two_mul, activation=activation)
    gb.add_output(y, out_dtype, (None, 24))
    lo, hi = (0, 256) if in_dtype == "uint8" else (-128, 128)
    return gb.build(), {"x": rng.integers(lo, hi, (8, 32)).astype(in_dtype)}, {"fused_qlinear": 1}


def _pc_gemm(rng, *, trans_b=False):
    p = _pc_params(rng, 32, 24)
    gb = _g("pc_gemm")
    x = gb.add_input("x", "int8", (None, 32))
    y = patterns.fc_layer_gemm(gb, x, p, "fc0", activation="Relu", trans_b=trans_b)
    gb.add_output(y, "int8", (None, 24))
    return gb.build(), {"x": _rng8(rng, (8, 32))}, {"fused_qlinear": 1}


def _pc_conv(rng, *, two_mul=False, activation="Relu", bias=True):
    m, c = 6, 3
    w = rng.normal(size=(m, c, 3, 3)).astype(np.float32) * 0.4
    w[rng.integers(0, m)] *= 20.0
    absmax = np.maximum(np.abs(w).max(axis=(1, 2, 3)), 1e-12)
    scale_w = (absmax / 127.0).astype(np.float32)
    w_q = quant.quantize(w, scale_w.reshape(-1, 1, 1, 1), "int8")
    b_q = quant.quantize_bias(rng.normal(size=(m,)).astype(np.float32) * 0.1, scale_w, 0.05) if bias else None
    rescale = quant.decompose_multipliers(scale_w.astype(np.float64) * 0.05 / 0.1)
    gb = _g("pc_conv")
    x = gb.add_input("x", "int8", (None, c, 8, 8))
    y = patterns.conv_layer(
        gb, x, w_q, b_q, rescale, "c0", pads=(1, 1, 1, 1), two_mul=two_mul, activation=activation
    )
    gb.add_output(y, "int8", (None, m, 8, 8))
    return gb.build(), {"x": _rng8(rng, (2, c, 8, 8))}, {"fused_qconv": 1}


def _pc_fc_then_lut(rng):
    """Per-channel FC feeding the int8-tanh LUT: the vector rescale composes
    with the (scalar-scale) LUT fusion — both chains still fuse."""
    p = _pc_params(rng, 32, 16)
    p = quant.quantize_linear_layer(
        p.weight_q.astype(np.float32) * 0.01, None, 0.05, patterns.TANH_INPUT_ABSMAX / 127.0, per_channel=True
    )
    gb = _g("pc_lut")
    x = gb.add_input("x", "int8", (None, 32))
    y = patterns.fc_int8_tanh(gb, x, p, "fc0")
    gb.add_output(y, "int8", (None, 16))
    return gb.build(), {"x": _rng8(rng, (8, 32))}, {"fused_qlinear": 1, "fused_lut": 1}


PER_CHANNEL_CASES = {
    "fc_two_mul": lambda rng: _pc_fc(rng, two_mul=True, bias=True),
    "fc_one_mul_relu": lambda rng: _pc_fc(rng, two_mul=False, activation="Relu"),
    "fc_no_bias": lambda rng: _pc_fc(rng, two_mul=True, bias=False),
    "fc_uint8_in": lambda rng: _pc_fc(rng, two_mul=True, in_dtype="uint8"),
    "fc_uint8_out": lambda rng: _pc_fc(rng, two_mul=True, activation="Relu", out_dtype="uint8"),
    "gemm": lambda rng: _pc_gemm(rng),
    "gemm_transB": lambda rng: _pc_gemm(rng, trans_b=True),
    "conv_one_mul": lambda rng: _pc_conv(rng, two_mul=False),
    "conv_two_mul": lambda rng: _pc_conv(rng, two_mul=True),
    "conv_no_bias": lambda rng: _pc_conv(rng, two_mul=True, bias=False, activation=None),
    "fc_then_lut": _pc_fc_then_lut,
}


class TestPerChannelFusedSweep:
    """Differential conformance: per-channel variants of every fused kernel,
    every backend, bit-exact against the reference runtime — and actually
    *fused* (no silent scalar-only fallback)."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("case", sorted(PER_CHANNEL_CASES))
    def test_per_channel_fused_matches_reference(self, case, backend):
        rng = np.random.default_rng(abs(hash(case)) % (2**31))
        model, feeds, want_fused = PER_CHANNEL_CASES[case](rng)
        ref = ReferenceRuntime(model).run(feeds)
        cm = compile_model(model, backend=backend, verify_passes=True)
        for kind, count in want_fused.items():
            assert cm.stats[kind] == count, (case, cm.stats)
        assert cm.stats["generic"] == 0, (case, cm.stats)
        got = cm.run(feeds)
        for k, want in ref.items():
            np.testing.assert_array_equal(got[k], want, err_msg=f"{case}/{backend}")


class TestShapePlumbingEndToEnd:
    def test_slice_squeeze_unsqueeze_through_full_pipeline(self):
        """The satellite case: a valid artifact using Slice/Squeeze/Unsqueeze
        compiles through the *default* path (optimize + fuse on) and matches
        the reference runtime bit-exactly."""
        rng = np.random.default_rng(7)
        gb = _g("plumb")
        x = gb.add_input("x", "int8", (4, 1, 10))
        sq_ax = gb.add_initializer("sq_ax", np.asarray([1], np.int64))
        st = gb.add_initializer("st", np.asarray([2], np.int64))
        en = gb.add_initializer("en", np.asarray([10], np.int64))
        ax = gb.add_initializer("ax", np.asarray([1], np.int64))
        us_ax = gb.add_initializer("us_ax", np.asarray([2], np.int64))
        s = gb.op("Squeeze", [x, sq_ax])  # (4, 10)
        sl = gb.op("Slice", [s, st, en, ax])  # (4, 8)
        u = gb.op("Unsqueeze", [sl, us_ax])  # (4, 8, 1)
        gb.add_output(u, "int8", (4, 8, 1))
        model = gb.build()
        feeds = {"x": _rng8(rng, (4, 1, 10))}
        ref = ReferenceRuntime(model).run(feeds)[u]
        for backend in ("ref", "interpret"):
            got = compile_model(model, backend=backend).run(feeds)[u]
            np.testing.assert_array_equal(got, ref)
