"""E6 (unit tier): exporter — JAX-side stacks → PQ-IR artifacts + quant report."""
import numpy as np

from repro.core.compile import compile_model
from repro.core.export import export_linear_stack, export_quant_report
from repro.core.runtime import ReferenceRuntime
from repro.core import quant


def test_export_linear_stack_roundtrip():
    rng = np.random.default_rng(0)
    ws = [rng.normal(size=(16, 32)).astype(np.float32) * 0.2,
          rng.normal(size=(32, 8)).astype(np.float32) * 0.2]
    bs = [rng.normal(size=(32,)).astype(np.float32) * 0.1, None]
    calib = rng.normal(size=(128, 16)).astype(np.float32)
    model = export_linear_stack(ws, bs, ["Relu", None], calib, name="exported")
    model.validate(standard_ops_only=True)
    xq = quant.quantize(calib[:4], eval(model.metadata["input_scale"]), "int8")
    ref = ReferenceRuntime(model).run({"input_q": xq})
    got = compile_model(model).run({"input_q": xq})
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k])


def test_export_quant_report_contents():
    rng = np.random.default_rng(1)
    ws = [rng.normal(size=(8, 8)).astype(np.float32) * 0.3]
    model = export_linear_stack(ws, [None], [None], rng.normal(size=(64, 8)).astype(np.float32))
    rep = export_quant_report(model)
    assert len(rep["layers"]) == 1
    layer = rep["layers"][0]
    assert layer["op"] == "MatMulInteger"
    assert 1 <= layer["quant_scale"] < 2**24  # integer-as-FLOAT bound
    assert layer["quant_shift_bits"] >= 0


def test_export_tanh_modes():
    rng = np.random.default_rng(2)
    ws = [rng.normal(size=(8, 8)).astype(np.float32) * 0.2,
          rng.normal(size=(8, 4)).astype(np.float32) * 0.2]
    calib = rng.normal(size=(64, 8)).astype(np.float32)
    for mode in ("int8", "fp16"):
        model = export_linear_stack(ws, [None, None], ["Tanh", None], calib, tanh_mode=mode)
        ops = [n.op_type for n in model.graph.toposorted()]
        assert ("Cast" in ops[5:9]) == (mode == "fp16")  # Fig 5 adds the f16 casts
        xq = quant.quantize(calib[:2], eval(model.metadata["input_scale"]), "int8")
        np.testing.assert_array_equal(
            ReferenceRuntime(model).run({"input_q": xq})[model.graph.outputs[0].name],
            compile_model(model).run({"input_q": xq})[model.graph.outputs[0].name],
        )
