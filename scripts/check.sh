#!/usr/bin/env bash
# One-step verification on a clean checkout:
#   1. tier-1 test suite (ROADMAP.md "Tier-1 verify" command)
#   2. fast end-to-end smoke: quantize → optimize → compile → bit-exact check
#
# Usage: scripts/check.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q "$@"

echo "== smoke: examples/quickstart.py =="
python examples/quickstart.py

echo "== all checks passed =="
