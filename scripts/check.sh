#!/usr/bin/env bash
# One-step verification on a clean checkout:
#   1. fast test tier (everything not marked `slow`; the heavyweight
#      model/serving/distributed tests run with CHECK_FULL=1 or the plain
#      ROADMAP.md tier-1 command `python -m pytest -x -q`)
#   2. fast end-to-end smoke: quantize → optimize → compile → bit-exact check
#
# Usage: scripts/check.sh [extra pytest args...]
#        CHECK_FULL=1 scripts/check.sh   # include the slow tier
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${CHECK_FULL:-0}" == "1" ]]; then
  echo "== full suite: pytest =="
  python -m pytest -x -q "$@"
else
  echo "== fast tier: pytest -m 'not slow' =="
  python -m pytest -x -q -m "not slow" "$@"
fi

echo "== smoke: examples/quickstart.py =="
python examples/quickstart.py

echo "== all checks passed =="
