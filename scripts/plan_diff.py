#!/usr/bin/env python
"""Structural diff of two AOT plan artifacts (repro-plan-v1 JSON).

The hardware-designer workflow: two versions of a compiled model are saved
with ``repro.backend.artifact.save_artifact``; this script shows what changed
*structurally* — steps (kernel / fusion kind / buffer slots), per-step tile
choices, buffer-pool size, axes, and the recorded hot scenario cells with
their tile sources — without loading either artifact (no jax, no kernels;
the npz sidecars are never opened).

Usage:
    python scripts/plan_diff.py old.json new.json

Exit status: 0 when the plans are structurally identical, 1 when they
differ, 2 on bad input — so it slots into CI pipelines as a drift gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Tuple

SCHEMA = "repro-plan-v1"

#: shape-record fields worth diffing per step (template + bound forms).
#: ``bits`` is the weight bitwidth of the sub-8-bit lane (absent = int8): a
#: w4 plan and its w8 twin have identical logical shapes but different
#: packed-weight layouts and HBM traffic, so they must never diff clean.
#: ``b/s/t/dh/bq`` are the fused-attention record (``bq`` = query row-block).
_TILE_KEYS = ("m", "k", "n", "kp", "np", "bm", "bk", "bn", "bits", "b", "s", "t", "dh", "bq")


def _load(path: str) -> Dict[str, Any]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {path}: {e}", file=sys.stderr)
        raise SystemExit(2)
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        detail = (
            f"not a {SCHEMA} artifact (schema={doc.get('schema')!r})"
            if isinstance(doc, dict)
            else "not a JSON object"
        )
        print(f"error: {path}: {detail}", file=sys.stderr)
        raise SystemExit(2)
    return doc


def _step_sig(sj: Dict[str, Any]) -> Dict[str, Any]:
    """The structural identity of one step: what a designer diffs."""
    params = sj.get("params", {})
    shape = params.get("shape", {})
    tiles = {}
    if isinstance(shape, dict):
        tiles = {k: shape[k] for k in _TILE_KEYS if k in shape}
    # ref-backend fused steps carry no shape record; the bitwidth then rides
    # as a plain weight_bits param (sub-8-bit only) and must still diff
    if "bits" not in tiles and "weight_bits" in params:
        tiles["bits"] = params["weight_bits"]
    return {
        "kernel": sj.get("kernel"),
        "kind": sj.get("kind"),
        "name": sj.get("name") or sj.get("kernel"),
        "in_slots": [a[1] for a in sj.get("args", []) if a[0] == "slot"],
        "out_slots": sj.get("out_slots", []),
        "tiles": tiles,
    }


def _fmt_tiles(tiles: Dict[str, Any]) -> str:
    return ",".join(f"{k}={tiles[k]}" for k in _TILE_KEYS if k in tiles) or "-"


def _state_sigs(plan: Dict[str, Any]) -> List[str]:
    """Persistent state-slot records: name, pinned slots, dtype and shape
    (the KV cache's seq extent rides in the shape — symbolic on a template,
    bound on a specialized plan).  A KV-carrying plan therefore never diffs
    clean against its stateless twin."""
    sigs = []
    for rec in plan.get("states", []):
        name, _inp, _out, in_slot, out_slot, dtype, shape = rec
        dims = "×".join(str(d) for d in shape) if shape else "?"
        sigs.append(f"{name}: %{in_slot}->%{out_slot} {dtype}[{dims}]")
    return sigs


def _cells(doc: Dict[str, Any]) -> Dict[str, Dict[str, str]]:
    """cell label -> {step name -> tile record incl. source}."""
    out: Dict[str, Dict[str, str]] = {}
    for cell in doc.get("cells", []):
        label = ",".join(f"{a}={v}" for a, v in sorted(cell["bindings"].items()))
        out[label] = {
            name: _fmt_tiles(rec) + f" [{rec.get('source', 'heuristic')}]"
            for name, rec in sorted(cell.get("tiles", {}).items())
        }
    return out


def diff(a: Dict[str, Any], b: Dict[str, Any]) -> Tuple[List[str], bool]:
    """Render the structural diff; returns (lines, changed)."""
    lines: List[str] = []
    changed = False

    def row(field: str, va: Any, vb: Any) -> None:
        nonlocal changed
        if va == vb:
            lines.append(f"  {field}: {va}")
        else:
            changed = True
            lines.append(f"  {field}: {va} -> {vb}  [changed]")

    pa, pb = a["plan"], b["plan"]
    row("backend", pa["backend"], pb["backend"])
    row("buffer slots", pa["num_slots"], pb["num_slots"])
    row("axes", ",".join(pa.get("axes", [])) or "-", ",".join(pb.get("axes", [])) or "-")
    row("state slots", "; ".join(_state_sigs(pa)) or "-", "; ".join(_state_sigs(pb)) or "-")
    row("steps", len(pa["steps"]), len(pb["steps"]))

    sa = [_step_sig(s) for s in pa["steps"]]
    sb = [_step_sig(s) for s in pb["steps"]]
    lines.append("  per-step:")
    for i in range(max(len(sa), len(sb))):
        xa: Optional[Dict] = sa[i] if i < len(sa) else None
        xb: Optional[Dict] = sb[i] if i < len(sb) else None
        if xa is None:
            changed = True
            lines.append(f"    step {i}: (absent) -> {xb['name']} {xb['kernel']}  [added]")
            continue
        if xb is None:
            changed = True
            lines.append(f"    step {i}: {xa['name']} {xa['kernel']} -> (absent)  [removed]")
            continue
        if xa == xb:
            lines.append(
                f"    step {i}: {xa['name']} {xa['kernel']} "
                f"slots {xa['in_slots']}->{xa['out_slots']} tiles {_fmt_tiles(xa['tiles'])}"
            )
            continue
        changed = True
        deltas = []
        for field in ("kernel", "kind", "name", "in_slots", "out_slots"):
            if xa[field] != xb[field]:
                deltas.append(f"{field} {xa[field]} -> {xb[field]}")
        if xa["tiles"] != xb["tiles"]:
            deltas.append(f"tiles {_fmt_tiles(xa['tiles'])} -> {_fmt_tiles(xb['tiles'])}")
        lines.append(f"    step {i}: {xa['name']}: " + "; ".join(deltas) + "  [changed]")

    ca, cb = _cells(a), _cells(b)
    lines.append("  hot cells:")
    if not ca and not cb:
        lines.append("    (none recorded)")
    for label in sorted(set(ca) | set(cb)):
        ta, tb = ca.get(label), cb.get(label)
        if ta is None:
            changed = True
            lines.append(f"    ({label}): only in {sys.argv[2] if len(sys.argv) > 2 else 'b'}  [added]")
        elif tb is None:
            changed = True
            lines.append(f"    ({label}): only in {sys.argv[1] if len(sys.argv) > 1 else 'a'}  [removed]")
        elif ta == tb:
            body = "; ".join(f"{n} {r}" for n, r in ta.items()) or "no fused steps"
            lines.append(f"    ({label}): {body}")
        else:
            changed = True
            for name in sorted(set(ta) | set(tb)):
                ra, rb = ta.get(name, "(absent)"), tb.get(name, "(absent)")
                if ra != rb:
                    lines.append(f"    ({label}) {name}: {ra} -> {rb}  [changed]")
    return lines, changed


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="structural diff of two repro-plan-v1 artifacts"
    )
    ap.add_argument("a", help="baseline artifact JSON")
    ap.add_argument("b", help="candidate artifact JSON")
    args = ap.parse_args(argv)
    a, b = _load(args.a), _load(args.b)
    print(f"plan diff: {args.a} vs {args.b}")
    lines, changed = diff(a, b)
    print("\n".join(lines))
    print("result: " + ("STRUCTURALLY DIFFERENT" if changed else "structurally identical"))
    return 1 if changed else 0


if __name__ == "__main__":
    sys.exit(main())
